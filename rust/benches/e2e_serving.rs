//! Bench: end-to-end serving — the scheduled + pooled logic engines
//! against a faithful replica of the pre-scheduling serving path
//! (per-image first layer -> `BitVec` -> `transpose_to_planes`, fresh
//! full-size scratch per block, per-sample `BitVec` last layer), at
//! plane widths 64/256/512, plus the threshold (Eq. 1 dot-product)
//! reference and coordinator sharding throughput.
//!
//! Self-contained: synthesizes a Table-5-style hidden layer from random
//! observations, exactly like `compile_load.rs` — no `make artifacts`
//! needed, so this runs in CI.  `NULLANET_BENCH_CAP` caps the ISF
//! pattern count (default 2000).
//!
//! Run: cargo bench --bench e2e_serving
//! Emits BENCH_serving.json (machine-readable medians: per-width batch
//! latency, amortized per-image latency, imgs/sec, the
//! scheduled-vs-pre-PR speedups, and a SIMD backend x width sweep with
//! generic-vs-avx2-vs-avx512 rows + `simd_speedup_*` ratios) — the
//! serving half of the perf trajectory, mirroring
//! BENCH_compile.json.  Cargo runs benches with CWD = the package root,
//! so the file lands at rust/BENCH_serving.json.  Set
//! NULLANET_BENCH_WRITE_BASELINE=<path> to also write the run as a
//! baseline candidate for rust/BENCH_serving.baseline.json.

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

use nullanet::bench_util::{bench, BenchResult, Table};
use nullanet::coordinator::{engine, engine::InferenceEngine, Coordinator, CoordinatorConfig};
use nullanet::isf::{extract, IsfConfig, LayerObservations};
use nullanet::jsonio::{num, obj, s, Json};
use nullanet::model::{Arch, NetArtifacts, Tensor, ThresholdLayer};
use nullanet::netlist::LogicTape;
use nullanet::simd;
use nullanet::synth::{optimize_layer, SynthConfig};
use nullanet::util::{transpose_to_planes, BitVec, BitWord, SplitMix64, W256, W512};

const N_IN: usize = 16;
const HIDDEN: usize = 20;
const N_OUT: usize = 10;
const BATCH: usize = 512;

fn tensor(shape: Vec<usize>, f32s: Vec<f32>) -> Tensor {
    Tensor { shape, f32s }
}

fn random_tensor(rng: &mut SplitMix64, shape: Vec<usize>) -> Tensor {
    let numel: usize = shape.iter().product();
    tensor(shape, (0..numel).map(|_| rng.normal() as f32).collect())
}

fn threshold_layer(rng: &mut SplitMix64, n_in: usize, n_out: usize) -> ThresholdLayer {
    ThresholdLayer {
        n_in,
        n_out,
        w: (0..n_in * n_out).map(|_| rng.normal() as f32).collect(),
        theta: (0..n_out).map(|_| rng.normal() as f32).collect(),
        flip: (0..n_out).map(|_| rng.bool(0.2)).collect(),
    }
}

fn observe(layer: &ThresholdLayer, rng: &mut SplitMix64, n_samples: usize) -> LayerObservations {
    let in_stride = (layer.n_in + 7) / 8;
    let out_stride = (layer.n_out + 7) / 8;
    let mut inputs = vec![0u8; n_samples * in_stride];
    let mut outputs = vec![0u8; n_samples * out_stride];
    for sample in 0..n_samples {
        let bits = BitVec::from_bools((0..layer.n_in).map(|_| rng.bool(0.5)));
        for i in bits.iter_ones() {
            inputs[sample * in_stride + i / 8] |= 1 << (i % 8);
        }
        let out = layer.eval(&bits);
        for j in out.iter_ones() {
            outputs[sample * out_stride + j / 8] |= 1 << (j % 8);
        }
    }
    LayerObservations {
        name: "hidden2".into(),
        n_in: layer.n_in,
        n_out: layer.n_out,
        inputs,
        outputs,
        n_samples,
    }
}

// ---------------------------------------------------------------------
// Pre-PR serving path, replicated verbatim: per-image first layer into a
// BitVec, transpose_to_planes, a freshly allocated full-n_planes scratch
// + output vec per tape per block, and a per-sample BitVec rebuild in
// front of the popcount last layer.
// ---------------------------------------------------------------------

struct NaiveLast {
    n_out: usize,
    w_eff: Vec<f32>,
    correction: Vec<f32>,
}

impl NaiveLast {
    fn new(w: &Tensor, sc: &Tensor, b: &Tensor) -> NaiveLast {
        let (n_in, n_out) = (w.shape[0], w.shape[1]);
        let mut w_eff = vec![0f32; n_in * n_out];
        let mut colsum = vec![0f32; n_out];
        for i in 0..n_in {
            for j in 0..n_out {
                let v = w.f32s[i * n_out + j] * sc.f32s[j];
                w_eff[i * n_out + j] = v;
                colsum[j] += v;
            }
        }
        let correction = (0..n_out).map(|j| b.f32s[j] - colsum[j]).collect();
        NaiveLast { n_out, w_eff, correction }
    }

    fn logits(&self, bits: &BitVec) -> Vec<f32> {
        let mut acc = vec![0f32; self.n_out];
        for i in bits.iter_ones() {
            let row = &self.w_eff[i * self.n_out..(i + 1) * self.n_out];
            for (j, &w) in row.iter().enumerate() {
                acc[j] += w;
            }
        }
        (0..self.n_out)
            .map(|j| 2.0 * acc[j] + self.correction[j])
            .collect()
    }
}

fn naive_first_layer(net: &NetArtifacts, img: &[f32]) -> BitVec {
    let w = &net.tensors["w1"];
    let sc = &net.tensors["scale1"];
    let b = &net.tensors["bias1"];
    let (n_in, n_out) = (w.shape[0], w.shape[1]);
    let mut z = vec![0f32; n_out];
    for (i, &x) in img.iter().enumerate().take(n_in) {
        if x == 0.0 {
            continue;
        }
        let row = &w.f32s[i * n_out..(i + 1) * n_out];
        for (j, &wv) in row.iter().enumerate() {
            z[j] += x * wv;
        }
    }
    BitVec::from_bools((0..n_out).map(|j| z[j] * sc.f32s[j] + b.f32s[j] >= 0.0))
}

fn naive_infer_batch<W: BitWord>(
    net: &NetArtifacts,
    tapes: &[LogicTape],
    last: &NaiveLast,
    images: &[&[f32]],
) -> Vec<Vec<f32>> {
    let mut out_all = Vec::with_capacity(images.len());
    for chunk in images.chunks(W::LANES) {
        let first: Vec<BitVec> = chunk.iter().map(|im| naive_first_layer(net, im)).collect();
        let width = first[0].len();
        let mut cur: Vec<W> = transpose_to_planes(&first, width);
        for tape in tapes {
            let mut out = vec![W::ZERO; tape.outputs.len()];
            let mut scratch = tape.make_scratch::<W>();
            tape.eval_into(&cur, &mut out, &mut scratch);
            cur = out;
        }
        for samp in 0..chunk.len() {
            let bits = BitVec::from_bools((0..cur.len()).map(|j| cur[j].get_lane(samp)));
            out_all.push(last.logits(&bits));
        }
    }
    out_all
}

fn main() {
    let mut rng = SplitMix64::new(42);
    let cap = std::env::var("NULLANET_BENCH_CAP")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(2000);

    // Synthesize the hidden layer (Table-5 style: one parameter-free
    // Boolean stage between the f32 first layer and the popcount last).
    let hidden = threshold_layer(&mut rng, HIDDEN, HIDDEN);
    let obs = observe(&hidden, &mut rng, 800);
    let isf = extract(&obs, &IsfConfig { max_patterns: cap });
    let opt = optimize_layer("hidden2", &isf, &SynthConfig::default());
    let tape = opt.tape;

    // The surrounding net: random f32 first/last layers + the threshold
    // form of the hidden layer for the reference engine.
    let mut tensors = BTreeMap::new();
    tensors.insert("w1".to_string(), random_tensor(&mut rng, vec![N_IN, HIDDEN]));
    tensors.insert("scale1".to_string(), tensor(vec![HIDDEN], vec![1.0; HIDDEN]));
    tensors.insert("bias1".to_string(), random_tensor(&mut rng, vec![HIDDEN]));
    tensors.insert("w2".to_string(), tensor(vec![HIDDEN, HIDDEN], hidden.w.clone()));
    tensors.insert("theta2".to_string(), tensor(vec![HIDDEN], hidden.theta.clone()));
    tensors.insert(
        "flip2".to_string(),
        tensor(vec![HIDDEN], hidden.flip.iter().map(|&f| f as u8 as f32).collect()),
    );
    tensors.insert("w3".to_string(), random_tensor(&mut rng, vec![HIDDEN, N_OUT]));
    tensors.insert("scale3".to_string(), tensor(vec![N_OUT], vec![1.0; N_OUT]));
    tensors.insert("bias3".to_string(), random_tensor(&mut rng, vec![N_OUT]));
    let net = NetArtifacts::detached(
        "bench".to_string(),
        Arch::Mlp { sizes: vec![N_IN, HIDDEN, HIDDEN, N_OUT] },
        tensors,
        f64::NAN,
    );

    // Sparse-ish random images (zero-skipping first layer sees ~50%).
    let images: Vec<Vec<f32>> = (0..BATCH)
        .map(|_| {
            (0..N_IN)
                .map(|_| if rng.bool(0.5) { 0.0 } else { rng.normal() as f32 })
                .collect()
        })
        .collect();
    let image_refs: Vec<&[f32]> = images.iter().map(|v| v.as_slice()).collect();

    let logic64 = engine::LogicEngine::<u64>::new(net.clone(), vec![tape.clone()]).unwrap();
    let logic256 = engine::LogicEngine::<W256>::new(net.clone(), vec![tape.clone()]).unwrap();
    let logic512 = engine::LogicEngine::<W512>::new(net.clone(), vec![tape.clone()]).unwrap();
    let thresh = engine::ThresholdEngine::new(net.clone()).unwrap();
    let last = NaiveLast::new(
        &net.tensors["w3"],
        &net.tensors["scale3"],
        &net.tensors["bias3"],
    );
    let tapes = vec![tape.clone()];

    // The scheduled engine must be bit-identical to the pre-PR path
    // (same f32 accumulation order throughout) — assert, don't assume.
    let want = naive_infer_batch::<u64>(&net, &tapes, &last, &image_refs);
    assert_eq!(logic64.infer_batch(&image_refs), want, "w64 scheduled != pre-PR path");
    assert_eq!(logic256.infer_batch(&image_refs), want, "w256 scheduled != pre-PR path");
    assert_eq!(logic512.infer_batch(&image_refs), want, "w512 scheduled != pre-PR path");

    // Per-backend engines for the SIMD sweep.  Every backend the CPU
    // offers must be bit-identical to the pre-PR path as well — the
    // sweep is only meaningful if all rows compute the same function.
    let backends = simd::available_backends();
    println!("simd sweep: {}", simd::describe(simd::select()));
    struct BackendEngines {
        backend: simd::Backend,
        e64: engine::LogicEngine<u64>,
        e256: engine::LogicEngine<W256>,
        e512: engine::LogicEngine<W512>,
    }
    let backend_engines: Vec<BackendEngines> = backends
        .iter()
        .map(|&backend| BackendEngines {
            backend,
            e64: engine::LogicEngine::<u64>::with_backend(net.clone(), tapes.clone(), backend)
                .unwrap(),
            e256: engine::LogicEngine::<W256>::with_backend(net.clone(), tapes.clone(), backend)
                .unwrap(),
            e512: engine::LogicEngine::<W512>::with_backend(net.clone(), tapes.clone(), backend)
                .unwrap(),
        })
        .collect();
    for be in &backend_engines {
        let bn = be.backend.name();
        assert_eq!(be.e64.infer_batch(&image_refs), want, "simd:{bn} w64 != pre-PR path");
        assert_eq!(be.e256.infer_batch(&image_refs), want, "simd:{bn} w256 != pre-PR path");
        assert_eq!(be.e512.infer_batch(&image_refs), want, "simd:{bn} w512 != pre-PR path");
    }

    let stats = logic64.schedule_stats().expect("logic engine stats");
    println!(
        "schedule: {} ops ({} stripped), max_live {} vs {} unscheduled planes \
         => {} scratch words/block",
        stats.n_ops,
        stats.ops_stripped,
        stats.max_live,
        stats.planes_unscheduled,
        stats.scratch_planes,
    );

    let budget = Duration::from_millis(700);
    let mut results: Vec<(String, usize, Option<String>, BenchResult)> = Vec::new();
    {
        let mut run = |name: &str, width: usize, backend: Option<&str>, f: &mut dyn FnMut()| {
            let r = bench(name, budget, f);
            results.push((name.to_string(), width, backend.map(str::to_string), r));
        };
        run("logic w64 scheduled+pooled", 64, None, &mut || {
            std::hint::black_box(logic64.infer_batch(std::hint::black_box(&image_refs)));
        });
        run("logic w64 pre-PR path", 64, None, &mut || {
            std::hint::black_box(naive_infer_batch::<u64>(
                &net,
                &tapes,
                &last,
                std::hint::black_box(&image_refs),
            ));
        });
        run("logic w256 scheduled+pooled", 256, None, &mut || {
            std::hint::black_box(logic256.infer_batch(std::hint::black_box(&image_refs)));
        });
        run("logic w256 pre-PR path", 256, None, &mut || {
            std::hint::black_box(naive_infer_batch::<W256>(
                &net,
                &tapes,
                &last,
                std::hint::black_box(&image_refs),
            ));
        });
        run("logic w512 scheduled+pooled", 512, None, &mut || {
            std::hint::black_box(logic512.infer_batch(std::hint::black_box(&image_refs)));
        });
        run("logic w512 pre-PR path", 512, None, &mut || {
            std::hint::black_box(naive_infer_batch::<W512>(
                &net,
                &tapes,
                &last,
                std::hint::black_box(&image_refs),
            ));
        });
        run("threshold (Eq.1 dot products)", 64, None, &mut || {
            std::hint::black_box(thresh.infer_batch(std::hint::black_box(&image_refs)));
        });
        // The SIMD backend x width sweep: one row per (backend, width).
        // "logic w{w} scheduled+pooled" above runs whatever NULLANET_
        // SIMD_BACKEND / detection selected; these rows pin the backend.
        for be in &backend_engines {
            let bn = be.backend.name();
            run(&format!("logic w64 simd:{bn}"), 64, Some(bn), &mut || {
                std::hint::black_box(be.e64.infer_batch(std::hint::black_box(&image_refs)));
            });
            run(&format!("logic w256 simd:{bn}"), 256, Some(bn), &mut || {
                std::hint::black_box(be.e256.infer_batch(std::hint::black_box(&image_refs)));
            });
            run(&format!("logic w512 simd:{bn}"), 512, Some(bn), &mut || {
                std::hint::black_box(be.e512.infer_batch(std::hint::black_box(&image_refs)));
            });
        }
    }

    let mut table = Table::new(
        &format!("End-to-end inference engines (batch = {BATCH})"),
        &["Engine", "batch latency", "per image", "images/s"],
    );
    for (name, _width, _backend, r) in &results {
        table.row(&[
            name.clone(),
            nullanet::bench_util::format_ns(r.median_ns),
            nullanet::bench_util::format_ns(r.median_ns / BATCH as f64),
            format!("{:.0}", r.throughput(BATCH as f64)),
        ]);
    }
    table.print();

    // Scheduled-vs-pre-PR deltas (the PR's acceptance evidence).
    let median = |name: &str| {
        results
            .iter()
            .find(|(n, _, _, _)| n == name)
            .map(|(_, _, _, r)| r.median_ns)
            .unwrap()
    };
    let mut speedups: Vec<(&str, f64)> = Vec::new();
    for width in [64usize, 256, 512] {
        let sched = median(&format!("logic w{width} scheduled+pooled"));
        let prepr = median(&format!("logic w{width} pre-PR path"));
        let ratio = prepr / sched;
        println!("w{width}: scheduled+pooled is {ratio:.2}x the pre-PR path");
        speedups.push(match width {
            64 => ("speedup_w64", ratio),
            256 => ("speedup_w256", ratio),
            _ => ("speedup_w512", ratio),
        });
    }

    // SIMD-backend-vs-generic deltas at each width (the tentpole's
    // acceptance evidence; generic is the 1.0x reference row).
    let mut simd_speedups: Vec<(String, f64)> = Vec::new();
    for width in [64usize, 256, 512] {
        let generic = median(&format!("logic w{width} simd:generic"));
        for &backend in &backends {
            if backend == simd::Backend::Generic {
                continue;
            }
            let bn = backend.name();
            let ratio = generic / median(&format!("logic w{width} simd:{bn}"));
            println!("w{width}: simd:{bn} is {ratio:.2}x generic");
            simd_speedups.push((format!("simd_speedup_w{width}_{bn}"), ratio));
        }
    }

    // Coordinator throughput under concurrent load: big batches sharded
    // into plane-width blocks over the worker pool.
    let logic64: Arc<dyn InferenceEngine> = Arc::new(
        engine::LogicEngine::<u64>::new(net.clone(), vec![tape.clone()]).unwrap(),
    );
    let logic512: Arc<dyn InferenceEngine> =
        Arc::new(engine::LogicEngine::<W512>::new(net.clone(), vec![tape.clone()]).unwrap());
    for (label, eng, workers) in [
        ("w64, 1 worker", Arc::clone(&logic64), 1),
        ("w64, 4 workers", Arc::clone(&logic64), 4),
        ("w512, 4 workers", Arc::clone(&logic512), 4),
    ] {
        let coord = Arc::new(Coordinator::start(
            eng,
            CoordinatorConfig { workers, ..Default::default() },
        ));
        let n_req = 4096;
        let t0 = Instant::now();
        let mut pending = Vec::with_capacity(n_req);
        for i in 0..n_req {
            pending.push(coord.submit(images[i % images.len()].clone()).unwrap());
        }
        for rx in pending {
            rx.recv().unwrap();
        }
        let dt = t0.elapsed();
        println!(
            "\ncoordinator ({label}, sharded batching): {} requests in {:.2?} = {:.0} req/s | {}",
            n_req,
            dt,
            n_req as f64 / dt.as_secs_f64(),
            coord.metrics.summary()
        );
    }

    // Machine-readable trajectory, mirroring BENCH_compile.json.
    let cpu = simd::cpu_features();
    let mut pairs = vec![
        ("bench", s("e2e_serving")),
        ("batch", num(BATCH as f64)),
        ("isf_cap", num(cap as f64)),
        ("simd_selected", s(simd::select().name())),
        ("cpu_avx2", Json::Bool(cpu.avx2)),
        ("cpu_avx512f", Json::Bool(cpu.avx512f)),
        ("tape_ops", num(stats.n_ops as f64)),
        ("ops_stripped", num(stats.ops_stripped as f64)),
        ("max_live", num(stats.max_live as f64)),
        ("planes_unscheduled", num(stats.planes_unscheduled as f64)),
        ("scratch_planes", num(stats.scratch_planes as f64)),
        (
            "results",
            Json::Arr(
                results
                    .iter()
                    .map(|(name, width, backend, r)| {
                        let mut row = vec![
                            ("name", s(name)),
                            ("width", num(*width as f64)),
                            ("median_ns", num(r.median_ns)),
                            // Median batch latency amortized per image —
                            // NOT a per-image latency percentile (see the
                            // server's latency histogram for those).
                            ("image_ns", num(r.median_ns / BATCH as f64)),
                            ("imgs_per_s", num(r.throughput(BATCH as f64))),
                            ("iters", num(r.iters as f64)),
                        ];
                        if let Some(b) = backend {
                            row.push(("backend", s(b)));
                        }
                        obj(row)
                    })
                    .collect(),
            ),
        ),
    ];
    for (k, v) in speedups {
        pairs.push((k, num(v)));
    }
    let mut json = obj(pairs);
    if let Json::Obj(map) = &mut json {
        for (k, v) in simd_speedups {
            map.insert(k, num(v));
        }
    }
    std::fs::write("BENCH_serving.json", json.to_string()).unwrap();
    println!("wrote BENCH_serving.json");

    // NULLANET_BENCH_WRITE_BASELINE=<path>: also emit this run as a
    // measured baseline candidate (same schema plus a provenance note),
    // so refreshing rust/BENCH_serving.baseline.json is one command:
    //   NULLANET_BENCH_WRITE_BASELINE=BENCH_serving.baseline.json \
    //     cargo bench --bench e2e_serving
    if let Ok(path) = std::env::var("NULLANET_BENCH_WRITE_BASELINE") {
        if !path.is_empty() {
            if let Json::Obj(map) = &mut json {
                map.insert(
                    "note".to_string(),
                    s("Measured baseline: written by cargo bench --bench e2e_serving \
                       with NULLANET_BENCH_WRITE_BASELINE set; regenerate the same \
                       way on a quiet runner."),
                );
            }
            std::fs::write(&path, json.to_string()).unwrap();
            println!("wrote baseline candidate {path}");
        }
    }
}
