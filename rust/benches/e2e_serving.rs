//! Bench: end-to-end serving — the paper's headline restated for the CPU
//! engine: synthesized-logic inference vs threshold (dot-product) vs the
//! PJRT fp32 baseline, with throughput, latency, and parameter-memory
//! traffic per inference.
//!
//! Run: cargo bench --bench e2e_serving

use std::sync::Arc;
use std::time::{Duration, Instant};

use nullanet::bench_util::{bench, Table};
use nullanet::coordinator::{engine, engine::InferenceEngine, Coordinator, CoordinatorConfig};
use nullanet::util::{W256, W512};
use nullanet::{data, isf, model, synth};

fn main() {
    let art = match model::Artifacts::load(&nullanet::artifacts_dir()) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("skipping (run `make artifacts` first): {e}");
            return;
        }
    };
    let net = art.net("net11").expect("net11").clone();
    let ds = data::Dataset::load(&art.test_path).expect("test set").take(512);
    let cap = std::env::var("NULLANET_BENCH_CAP")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(2000);

    // Build the three engines.
    let obs = isf::load_observations(&net.dir.join("activations.bin")).unwrap();
    let tapes: Vec<_> = obs
        .iter()
        .map(|o| {
            let l = isf::extract(o, &isf::IsfConfig { max_patterns: cap });
            synth::optimize_layer(&o.name, &l, &synth::SynthConfig::default()).tape
        })
        .collect();
    let logic = Arc::new(engine::LogicEngine::<u64>::new(net.clone(), tapes.clone()).unwrap());
    let logic256 =
        Arc::new(engine::LogicEngine::<W256>::new(net.clone(), tapes.clone()).unwrap());
    let logic512 = Arc::new(engine::LogicEngine::<W512>::new(net.clone(), tapes).unwrap());
    let thresh = Arc::new(engine::ThresholdEngine::new(net.clone()).unwrap());
    let xla = engine::XlaEngine::from_net(&net, "model_b64", 64, 784, 10)
        .ok()
        .map(Arc::new);

    // Batch = 512 so the wider planes get full blocks (the 64-lane
    // engine chews through it in 8 passes).
    let n_bench = 512.min(ds.n);
    let images: Vec<&[f32]> = (0..n_bench).map(|i| ds.image(i)).collect();
    let budget = Duration::from_millis(1500);
    let mut table = Table::new(
        &format!("End-to-end inference engines (batch = {n_bench})"),
        &["Engine", "batch latency", "images/s", "param bytes/inference"],
    );
    let mut add_row = |name: &str, eng: &dyn InferenceEngine| {
        let r = bench(&format!("{name} batch{n_bench}"), budget, || {
            std::hint::black_box(eng.infer_batch(std::hint::black_box(&images)));
        });
        table.row(&[
            name.into(),
            nullanet::bench_util::format_ns(r.median_ns),
            format!("{:.0}", r.throughput(n_bench as f64)),
            eng.param_bytes_per_inference().to_string(),
        ]);
    };
    add_row("logic w64 (synthesized tapes)", &*logic);
    add_row("logic w256 (synthesized tapes)", &*logic256);
    add_row("logic w512 (synthesized tapes)", &*logic512);
    add_row("threshold (Eq.1 dot products)", &*thresh);
    if let Some(x) = &xla {
        add_row("xla fp32 (PJRT baseline)", &**x);
    }
    table.print();

    // Coordinator throughput under concurrent load: big batches are
    // sharded into plane-width blocks over the worker pool.
    for (label, eng, workers) in [
        ("w64, 1 worker", Arc::clone(&logic) as Arc<dyn InferenceEngine>, 1),
        ("w64, 4 workers", Arc::clone(&logic) as Arc<dyn InferenceEngine>, 4),
        ("w512, 4 workers", Arc::clone(&logic512) as Arc<dyn InferenceEngine>, 4),
    ] {
        let coord = Arc::new(Coordinator::start(
            eng,
            CoordinatorConfig { workers, ..Default::default() },
        ));
        let n_req = 4096;
        let t0 = Instant::now();
        let mut pending = Vec::with_capacity(n_req);
        for i in 0..n_req {
            pending.push(coord.submit(ds.image(i % ds.n).to_vec()).unwrap());
        }
        for rx in pending {
            rx.recv().unwrap();
        }
        let dt = t0.elapsed();
        println!(
            "\ncoordinator ({label}, sharded batching): {} requests in {:.2?} = {:.0} req/s | {}",
            n_req,
            dt,
            n_req as f64 / dt.as_secs_f64(),
            coord.metrics.summary()
        );
    }
}
