//! Bench: Table 8 — hardware cost of the synthesized conv2 per-patch
//! kernels of Net 2.1.b (90 bits -> 20 bits).
//!
//! Run: cargo bench --bench table8_cnn_kernels

use nullanet::bench_util::Table;
use nullanet::cost::{FpgaModel, MAC16, MAC32};
use nullanet::{isf, model, synth};

fn main() {
    let art = match model::Artifacts::load(&nullanet::artifacts_dir()) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("skipping (run `make artifacts` first): {e}");
            return;
        }
    };
    let net = art.net("net21").expect("net21");
    let obs = isf::load_observations(&net.dir.join("activations.bin")).expect("activations");
    let o = &obs[0];
    let fpga = FpgaModel::default();

    let mut table = Table::new(
        "Table 8: conv2 per-patch kernel hardware cost (paper vs ours)",
        &["Config", "ALMs", "Registers", "Fmax (MHz)", "Latency (ns)", "Power (mW)", "x MAC32", "x MAC16"],
    );
    table.row(&[
        "Paper".into(), "15,990".into(), "110".into(), "70.12".into(), "14.26".into(), "41.77".into(),
        "30".into(), "82".into(),
    ]);
    for cap in [3000usize, 8000] {
        let t0 = std::time::Instant::now();
        let layer_isf = isf::extract(o, &isf::IsfConfig { max_patterns: cap });
        let s = synth::optimize_layer(&o.name, &layer_isf, &synth::SynthConfig::default());
        assert_eq!(synth::verify_layer(&layer_isf, &s), 0);
        let c = s.hw_cost(&fpga);
        table.row(&[
            format!("Ours (cap {cap}, {:.0?})", t0.elapsed()),
            c.alms.to_string(),
            c.registers.to_string(),
            format!("{:.2}", c.fmax_mhz),
            format!("{:.2}", c.latency_ns),
            format!("{:.2}", c.power_mw),
            format!("{:.0}", c.alms as f64 / MAC32.alms as f64),
            format!("{:.0}", c.alms as f64 / MAC16.alms as f64),
        ]);
    }
    table.print();
    println!(
        "\nshape check: kernel logic >> one MAC, << 1,800 parallel MACs (paper: 30x / 60x-fewer)\n\
         memory: 110 bits I/O per patch vs 28.13 KB fp32 = 2095x fewer accesses"
    );
}
