//! Bench: Table 6 — per-layer MAC + memory accounting for Net 1.1.b vs
//! Net 1.2, using the measured ALM count of the synthesized layers.
//!
//! Run: cargo bench --bench table6_layer_costs

use nullanet::bench_util::Table;
use nullanet::cost::{
    dense_layer_cost, dram_energy_pj, logic_mac_equivalents, FpgaModel, LayerRealization,
};
use nullanet::{isf, model, synth};

fn main() {
    // Measured ALMs when artifacts are present; paper's count otherwise.
    let alms = match model::Artifacts::load(&nullanet::artifacts_dir()) {
        Ok(art) => {
            let net = art.net("net11").expect("net11");
            let obs = isf::load_observations(&net.dir.join("activations.bin")).unwrap();
            let fpga = FpgaModel::default();
            let cap = std::env::var("NULLANET_BENCH_CAP")
                .ok()
                .and_then(|v| v.parse().ok())
                .unwrap_or(2000);
            let stages: Vec<_> = obs
                .iter()
                .map(|o| {
                    let l = isf::extract(o, &isf::IsfConfig { max_patterns: cap });
                    synth::optimize_layer(&o.name, &l, &synth::SynthConfig::default())
                        .hw_cost(&fpga)
                })
                .collect();
            fpga.cost_pipeline(&stages).alms
        }
        Err(_) => {
            eprintln!("artifacts missing; using the paper's ALM count");
            112_173
        }
    };

    let f32mac = LayerRealization::MacFloat { bytes_per_word: 4 };
    let fc1 = dense_layer_cost("FC1", 784, 100, f32mac);
    let fc2 = dense_layer_cost("FC2", 100, 100, f32mac);
    let fc4b = dense_layer_cost("FC4", 100, 10, LayerRealization::MacBinaryInput { bytes_per_word: 4 });
    let fc4 = dense_layer_cost("FC4", 100, 10, f32mac);
    let logic_eq = logic_mac_equivalents(alms);
    let logic_mem = 400.0 / 8.0;

    let mut t = Table::new(
        "Table 6: cost of realizing Net 1.1.b vs Net 1.2",
        &["Layer", "1.1.b MACs", "1.1.b Mem (B)", "1.2 MACs", "1.2 Mem (B)"],
    );
    t.row(&["FC1".into(), format!("{}", fc1.macs), format!("{}", fc1.memory_bytes), format!("{}", fc1.macs), format!("{}", fc1.memory_bytes)]);
    t.row(&["FC2+FC3".into(), format!("{:.0}", logic_eq), format!("{}", logic_mem), format!("{}", 2.0 * fc2.macs), format!("{}", 2.0 * fc2.memory_bytes)]);
    t.row(&["FC4".into(), format!("{}", fc4b.macs), format!("{}", fc4b.memory_bytes), format!("{}", fc4.macs), format!("{}", fc4.memory_bytes)]);
    let ours = (fc1.macs + logic_eq + fc4b.macs, fc1.memory_bytes + logic_mem + fc4b.memory_bytes);
    let base = (fc1.macs + 2.0 * fc2.macs + fc4.macs, fc1.memory_bytes + 2.0 * fc2.memory_bytes + fc4.memory_bytes);
    t.row(&["TOTAL".into(), format!("{:.0}", ours.0), format!("{:.0}", ours.1), format!("{:.0}", base.0), format!("{:.0}", base.1)]);
    t.print();
    println!(
        "paper totals: 79,607 MACs / 1,266,575 B vs 99,400 MACs / 1,590,400 B (20% / 20% savings)\n\
         ours:        {:.0} MACs / {:.0} B vs {:.0} MACs / {:.0} B ({:.0}% / {:.0}% savings)",
        ours.0, ours.1, base.0, base.1,
        (1.0 - ours.0 / base.0) * 100.0,
        (1.0 - ours.1 / base.1) * 100.0,
    );
    println!(
        "DRAM energy per inference (Table 2 midpoints): ours {:.1} µJ vs baseline {:.1} µJ",
        dram_energy_pj(ours.1) / 1e6,
        dram_energy_pj(base.1) / 1e6
    );
}
