//! Bench: Tables 1–3 — the paper's constant tables plus live timing of
//! the behavioural FP units that anchor the MAC baseline.
//!
//! Run: cargo bench --bench table3_fp_ops

use std::time::Duration;

use nullanet::arith::{f16_mac, f32_mac, mac_dot_f16, mac_dot_f32, F16};
use nullanet::bench_util::{bench, Table};
use nullanet::cost::{TABLE1, TABLE2, TABLE3};
use nullanet::util::SplitMix64;

fn main() {
    // Tables 1 and 2 are constants (latency/energy of the motivating
    // hardware); print them in paper layout.
    let mut t1 = Table::new("Table 1: Haswell latency (paper constants)", &["Operation", "Latency (cycles)"]);
    for r in TABLE1 {
        t1.row(&[r.name.into(), if r.cycles_lo == r.cycles_hi { format!("{}", r.cycles_lo) } else { format!("{} - {}", r.cycles_lo, r.cycles_hi) }]);
    }
    t1.print();
    let mut t2 = Table::new("Table 2: 45nm energy (paper constants)", &["Operation", "Bits", "pJ"]);
    for r in TABLE2 {
        t2.row(&[r.name.into(), r.bits.to_string(), if r.pj_lo == r.pj_hi { format!("{}", r.pj_lo) } else { format!("{} - {}", r.pj_lo, r.pj_hi) }]);
    }
    t2.print();

    let mut t3 = Table::new(
        "Table 3: FP units — paper P&R numbers + our behavioural-unit timings",
        &["Unit", "ALMs", "Fmax (MHz)", "Latency (ns)", "Power (mW)", "behavioural (this CPU)"],
    );
    let mut rng = SplitMix64::new(7);
    let xs: Vec<f32> = (0..256).map(|_| rng.normal() as f32).collect();
    let ws: Vec<f32> = (0..256).map(|_| rng.normal() as f32).collect();
    let budget = Duration::from_millis(300);

    for u in TABLE3 {
        let r = match (u.name, u.bits) {
            ("Add", 16) => bench("f16_add x256", budget, || {
                let mut acc = F16::from_f32(0.0);
                for &x in &xs {
                    acc = nullanet::arith::f16_add(acc, F16::from_f32(std::hint::black_box(x)));
                }
                std::hint::black_box(acc);
            }),
            ("Multiply", 16) => bench("f16_mul x256", budget, || {
                let mut acc = F16::from_f32(1.0);
                for &x in &xs {
                    acc = nullanet::arith::f16_mul(acc, F16::from_f32(std::hint::black_box(x)));
                }
                std::hint::black_box(acc);
            }),
            ("MAC", 16) => bench("f16_mac x256", budget, || {
                let mut acc = F16::from_f32(0.0);
                for (&x, &w) in xs.iter().zip(&ws) {
                    acc = f16_mac(acc, F16::from_f32(x), F16::from_f32(w));
                }
                std::hint::black_box(acc);
            }),
            ("Add", 32) => bench("f32_add x256", budget, || {
                let mut acc = 0f32;
                for &x in &xs {
                    acc = nullanet::arith::f32_add(acc, std::hint::black_box(x));
                }
                std::hint::black_box(acc);
            }),
            ("Multiply", 32) => bench("f32_mul x256", budget, || {
                let mut acc = 1f32;
                for &x in &xs {
                    acc = nullanet::arith::f32_mul(acc, std::hint::black_box(x));
                }
                std::hint::black_box(acc);
            }),
            _ => bench("f32_mac x256", budget, || {
                let mut acc = 0f32;
                for (&x, &w) in xs.iter().zip(&ws) {
                    acc = f32_mac(acc, x, w);
                }
                std::hint::black_box(acc);
            }),
        };
        t3.row(&[
            format!("{} ({})", u.name, u.bits),
            u.alms.to_string(),
            format!("{:.2}", u.fmax_mhz),
            format!("{:.2}", u.latency_ns),
            format!("{:.2}", u.power_mw),
            format!("{:.1} ns/op", r.median_ns / 256.0),
        ]);
    }
    t3.print();

    // MAC-dot comparison (the layer inner loop both baselines use).
    let r32 = bench("mac_dot_f32 n=256", budget, || {
        std::hint::black_box(mac_dot_f32(&xs, &ws));
    });
    let r16 = bench("mac_dot_f16 n=256", budget, || {
        std::hint::black_box(mac_dot_f16(&xs, &ws));
    });
    println!(
        "\nmac_dot 256-elem: f32 {:.1} ns/MAC, f16 (software) {:.1} ns/MAC",
        r32.median_ns / 256.0,
        r16.median_ns / 256.0
    );
}
