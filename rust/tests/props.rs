//! Property tests over the core invariants (prop mini-framework).

use nullanet::aig::{self, Aig, Lit};
use nullanet::logic::{minimize, Cover, Cube, EspressoConfig, IsfFunction, TruthTable};
use nullanet::netlist::verify::{self, code};
use nullanet::netlist::{LogicTape, ScheduledTape, TapeOp};
use nullanet::prop::check;
use nullanet::simd::{self, PlaneKernels};
use nullanet::util::{BitVec, BitWord, SplitMix64, W128, W256, W512};

fn random_isf(rng: &mut SplitMix64, max_vars: usize, max_pats: usize) -> IsfFunction {
    let n = rng.range(2, max_vars);
    let mut seen = std::collections::HashSet::new();
    let mut on = vec![];
    let mut off = vec![];
    for _ in 0..rng.range(1, max_pats) {
        let p = BitVec::from_bools((0..n).map(|_| rng.bool(0.5)));
        if seen.insert(p.clone()) {
            if rng.bool(0.5) {
                on.push(p);
            } else {
                off.push(p);
            }
        }
    }
    IsfFunction::from_minterms(n, &on, &off)
}

#[test]
fn espresso_covers_on_avoids_off() {
    check("espresso-on-off", 60, |rng| {
        let f = random_isf(rng, 14, 120);
        let (cover, _) = minimize(&f, &EspressoConfig::default());
        for &i in &f.on {
            assert!(cover.covers(&f.patterns.row_bitvec(i as usize)), "ON uncovered");
        }
        for &i in &f.off {
            assert!(!cover.covers(&f.patterns.row_bitvec(i as usize)), "OFF covered");
        }
    });
}

#[test]
fn espresso_cubes_are_prime_and_irredundant() {
    check("espresso-prime-irredundant", 30, |rng| {
        let f = random_isf(rng, 10, 60);
        let (cover, _) = minimize(&f, &EspressoConfig::default());
        // Primality.
        for c in &cover.cubes {
            for v in c.care_mask().iter_ones() {
                let mut raised = c.clone();
                raised.raise(v);
                assert!(
                    f.off.iter().any(|&i| raised.covers(&f.patterns.row_bitvec(i as usize))),
                    "cube not prime"
                );
            }
        }
        // Irredundancy: dropping any cube must uncover some ON pattern.
        for drop in 0..cover.len() {
            let rest: Vec<Cube> = cover
                .cubes
                .iter()
                .enumerate()
                .filter(|(i, _)| *i != drop)
                .map(|(_, c)| c.clone())
                .collect();
            let rest = Cover::from_cubes(cover.n_vars, rest);
            let uncovered = f
                .on
                .iter()
                .any(|&i| !rest.covers(&f.patterns.row_bitvec(i as usize)));
            assert!(uncovered, "cube {drop} redundant");
        }
    });
}

#[test]
fn synth_pipeline_preserves_function_end_to_end() {
    // espresso -> factor -> balance -> rewrite -> refactor -> tape must
    // still realize the ISF.
    check("synth-preserves", 25, |rng| {
        let f = random_isf(rng, 10, 80);
        let (cover, _) = minimize(&f, &EspressoConfig::default());
        let n = f.n_vars();
        let mut g = Aig::new(n);
        let pis: Vec<Lit> = (0..n).map(|i| g.pi(i)).collect();
        let root = aig::factor_cover(&mut g, &cover, &pis);
        g.add_output(root);
        let opt = aig::balance(&aig::refactor(
            &aig::rewrite(&g, &aig::RewriteConfig::default()),
            &aig::RefactorConfig::default(),
        ));
        let tape = LogicTape::from_aig(&opt);
        for &i in f.on.iter().chain(&f.off) {
            let p = f.patterns.row_bitvec(i as usize);
            let row: Vec<bool> = (0..n).map(|v| p.get(v)).collect();
            let out = tape.eval_batch(&[row])[0][0];
            let want = f.on.contains(&i);
            assert_eq!(out, want, "pattern {i}");
        }
    });
}

#[test]
fn bitsim_equals_scalar_eval() {
    check("bitsim-equals-scalar", 30, |rng| {
        let n = rng.range(2, 10);
        let mut g = Aig::new(n);
        let mut lits: Vec<Lit> = (0..n).map(|i| g.pi(i)).collect();
        for _ in 0..rng.range(1, 80) {
            let a = lits[rng.range(0, lits.len())];
            let b = lits[rng.range(0, lits.len())];
            lits.push(g.and(
                if rng.bool(0.5) { a.not() } else { a },
                if rng.bool(0.5) { b.not() } else { b },
            ));
        }
        for _ in 0..rng.range(1, 4) {
            let o = lits[rng.range(0, lits.len())];
            g.add_output(if rng.bool(0.5) { o.not() } else { o });
        }
        let tape = LogicTape::from_aig(&g);
        let rows: Vec<Vec<bool>> = (0..rng.range(1, 64))
            .map(|_| (0..n).map(|_| rng.bool(0.5)).collect())
            .collect();
        let fast = tape.eval_batch(&rows);
        for (row, out) in rows.iter().zip(fast) {
            assert_eq!(out, g.eval(row));
        }
    });
}

#[test]
fn tape_eval_matches_sim_reference_at_every_width() {
    // The generic multi-word eval must agree with the AIG word simulator
    // (the semantic reference) at 64, 128, 256 and 512 lanes, on random
    // AIGs and random inputs.
    fn random_aig(rng: &mut SplitMix64) -> Aig {
        let n = rng.range(2, 10);
        let mut g = Aig::new(n);
        let mut lits: Vec<Lit> = (0..n).map(|i| g.pi(i)).collect();
        for _ in 0..rng.range(1, 120) {
            let a = lits[rng.range(0, lits.len())];
            let b = lits[rng.range(0, lits.len())];
            lits.push(g.and(
                if rng.bool(0.5) { a.not() } else { a },
                if rng.bool(0.5) { b.not() } else { b },
            ));
        }
        for _ in 0..rng.range(1, 5) {
            let o = lits[rng.range(0, lits.len())];
            g.add_output(if rng.bool(0.5) { o.not() } else { o });
        }
        g
    }

    fn agree_at_width<W: BitWord>(g: &Aig, tape: &LogicTape, rng: &mut SplitMix64) {
        let inputs: Vec<W> = (0..g.n_pis())
            .map(|_| W::from_lanes(|_| rng.bool(0.5)))
            .collect();
        let want = aig::sim_words_wide(g, &inputs);
        let mut got = vec![W::ZERO; g.outputs.len()];
        let mut scratch = tape.make_scratch::<W>();
        tape.eval_into(&inputs, &mut got, &mut scratch);
        assert_eq!(got, want, "width {}", W::LANES);
    }

    check("tape-matches-sim-all-widths", 25, |rng| {
        let g = random_aig(rng);
        let tape = LogicTape::from_aig(&g);
        agree_at_width::<u64>(&g, &tape, rng);
        agree_at_width::<W128>(&g, &tape, rng);
        agree_at_width::<W256>(&g, &tape, rng);
        agree_at_width::<W512>(&g, &tape, rng);
    });
}

#[test]
fn scheduled_tape_is_lane_identical_at_all_widths() {
    // The liveness-compacted ScheduledTape must be lane-for-lane
    // identical to LogicTape::eval_into at every serving width, on
    // random AIGs with random complement/output structure — including
    // tapes reassembled via from_parts, which is exactly how the .nnc
    // artifact loader rebuilds them before the engine schedules them.
    fn random_aig(rng: &mut SplitMix64) -> Aig {
        let n = rng.range(2, 12);
        let mut g = Aig::new(n);
        let mut lits: Vec<Lit> = (0..n).map(|i| g.pi(i)).collect();
        for _ in 0..rng.range(1, 160) {
            let a = lits[rng.range(0, lits.len())];
            let b = lits[rng.range(0, lits.len())];
            lits.push(g.and(
                if rng.bool(0.5) { a.not() } else { a },
                if rng.bool(0.5) { b.not() } else { b },
            ));
        }
        for _ in 0..rng.range(1, 6) {
            let o = lits[rng.range(0, lits.len())];
            g.add_output(if rng.bool(0.5) { o.not() } else { o });
        }
        g
    }

    fn agree_at_width<W: BitWord>(tape: &LogicTape, sched: &ScheduledTape, rng: &mut SplitMix64) {
        let inputs: Vec<W> = (0..tape.n_inputs)
            .map(|_| W::from_lanes(|_| rng.bool(0.5)))
            .collect();
        let mut want = vec![W::ZERO; tape.outputs.len()];
        let mut got = vec![W::ZERO; tape.outputs.len()];
        tape.eval_into(&inputs, &mut want, &mut tape.make_scratch());
        let mut scratch = sched.make_scratch::<W>();
        sched.eval_into(&inputs, &mut got, &mut scratch);
        assert_eq!(got, want, "width {}", W::LANES);
        // Scratch is reusable: a second pass on the same (dirty) buffer
        // must not change the answer.
        sched.eval_into(&inputs, &mut got, &mut scratch);
        assert_eq!(got, want, "width {} (reused scratch)", W::LANES);
    }

    check("scheduled-lane-identical-all-widths", 25, |rng| {
        let g = random_aig(rng);
        let tape = LogicTape::from_aig(&g);
        let sched = ScheduledTape::new(&tape);
        // Compaction never grows the working set.
        assert!(sched.scratch_planes() <= tape.n_planes());
        agree_at_width::<u64>(&tape, &sched, rng);
        agree_at_width::<W256>(&tape, &sched, rng);
        agree_at_width::<W512>(&tape, &sched, rng);
        // The .nnc loader path: reassemble from serialized parts, then
        // schedule.  Must produce the identical schedule and outputs.
        let rebuilt =
            LogicTape::from_parts(tape.n_inputs, tape.ops.clone(), tape.outputs.clone()).unwrap();
        let resched = ScheduledTape::new(&rebuilt);
        assert_eq!(resched, sched, "from_parts round trip changed the schedule");
        agree_at_width::<u64>(&rebuilt, &resched, rng);
        agree_at_width::<W512>(&rebuilt, &resched, rng);
    });
}

#[test]
fn scheduled_tape_strips_exactly_the_dead_cone() {
    // Growing a random AIG, then outputting only the first half of its
    // nodes: everything the kept outputs can't reach must be stripped,
    // and the stripped tape must still agree with the full one.
    check("scheduled-dead-strip", 20, |rng| {
        let n = rng.range(2, 8);
        let mut g = Aig::new(n);
        let mut lits: Vec<Lit> = (0..n).map(|i| g.pi(i)).collect();
        for _ in 0..rng.range(10, 80) {
            let a = lits[rng.range(0, lits.len())];
            let b = lits[rng.range(0, lits.len())];
            lits.push(g.and(
                if rng.bool(0.5) { a.not() } else { a },
                if rng.bool(0.5) { b.not() } else { b },
            ));
        }
        // Output only from the early nodes: late ANDs are dead weight.
        let o = lits[rng.range(0, lits.len() / 2)];
        g.add_output(if rng.bool(0.5) { o.not() } else { o });
        let tape = LogicTape::from_aig(&g);
        let sched = ScheduledTape::new(&tape);
        assert_eq!(
            sched.n_ops() + sched.stats().ops_stripped,
            tape.n_ops(),
            "stripped + kept != total"
        );
        let inputs: Vec<u64> = (0..n).map(|_| rng.next_u64()).collect();
        let mut want = vec![0u64; 1];
        let mut got = vec![0u64; 1];
        tape.eval_into(&inputs, &mut want, &mut tape.make_scratch());
        sched.eval_into(&inputs, &mut got, &mut sched.make_scratch());
        assert_eq!(got, want);
    });
}

#[test]
fn simd_backends_lane_identical_on_scheduled_tapes() {
    // Every plane-kernel backend this CPU offers must be lane-for-lane
    // identical to ScheduledTape::eval_into (the scalar reference) at
    // every serving width — including a second pass over the same dirty
    // scratch buffer, which is exactly how the engine pools reuse it.
    fn random_aig(rng: &mut SplitMix64) -> Aig {
        let n = rng.range(2, 12);
        let mut g = Aig::new(n);
        let mut lits: Vec<Lit> = (0..n).map(|i| g.pi(i)).collect();
        for _ in 0..rng.range(1, 160) {
            let a = lits[rng.range(0, lits.len())];
            let b = lits[rng.range(0, lits.len())];
            lits.push(g.and(
                if rng.bool(0.5) { a.not() } else { a },
                if rng.bool(0.5) { b.not() } else { b },
            ));
        }
        for _ in 0..rng.range(1, 6) {
            let o = lits[rng.range(0, lits.len())];
            g.add_output(if rng.bool(0.5) { o.not() } else { o });
        }
        g
    }

    fn agree_at_width<W: BitWord>(
        sched: &ScheduledTape,
        kern: &dyn PlaneKernels,
        rng: &mut SplitMix64,
    ) {
        let inputs: Vec<W> = (0..sched.n_inputs())
            .map(|_| W::from_lanes(|_| rng.bool(0.5)))
            .collect();
        let mut want = vec![W::ZERO; sched.n_outputs()];
        let mut got = vec![W::ZERO; sched.n_outputs()];
        sched.eval_into(&inputs, &mut want, &mut sched.make_scratch());
        let mut scratch = sched.make_scratch::<W>();
        sched.eval_into_kern(kern, &inputs, &mut got, &mut scratch);
        let bn = kern.backend().name();
        assert_eq!(got, want, "simd:{bn} width {}", W::LANES);
        // Scratch is reusable: a second pass on the same (dirty) buffer
        // must not change the answer.
        sched.eval_into_kern(kern, &inputs, &mut got, &mut scratch);
        assert_eq!(got, want, "simd:{bn} width {} (reused dirty scratch)", W::LANES);
    }

    check("simd-lane-identical-all-backends", 20, |rng| {
        let g = random_aig(rng);
        let sched = ScheduledTape::new(&LogicTape::from_aig(&g));
        for backend in simd::available_backends() {
            let kern = backend.kernels();
            agree_at_width::<u64>(&sched, kern, rng);
            agree_at_width::<W256>(&sched, kern, rng);
            agree_at_width::<W512>(&sched, kern, rng);
        }
    });
}

#[test]
fn simd_f32_kernels_bit_identical_across_backends() {
    // The first-layer GEMM, sign-bit plane writer and popcount last
    // layer must produce bit-identical f32s/planes on every backend —
    // same accumulation order, no FMA contraction, same `>= 0.0`
    // semantics — for random shapes including ragged SIMD tails.
    check("simd-f32-kernels-bit-identical", 20, |rng| {
        let n_in = rng.range(1, 40);
        let n_out = rng.range(1, 40);
        let n_limbs = rng.range(1, 9);
        let img: Vec<f32> = (0..n_in)
            .map(|_| if rng.bool(0.3) { 0.0 } else { rng.normal() as f32 })
            .collect();
        let w: Vec<f32> = (0..n_in * n_out).map(|_| rng.normal() as f32).collect();
        let scale: Vec<f32> = (0..n_out).map(|_| rng.normal() as f32).collect();
        let bias: Vec<f32> = (0..n_out).map(|_| rng.normal() as f32).collect();
        let lane = rng.range(0, n_limbs * 64);
        let n = rng.range(1, 129);
        let limbs: Vec<u64> = (0..n.div_ceil(64)).map(|_| rng.next_u64()).collect();
        let row: Vec<f32> = (0..n_out).map(|_| rng.normal() as f32).collect();

        let generic = simd::Backend::Generic.kernels();
        let mut z_ref = vec![f32::NAN; n_out];
        generic.gemm_zero_skip(&img, &w, n_out, &mut z_ref);
        let mut planes_ref = vec![0u64; n_out * n_limbs];
        generic.sign_planes(&z_ref, &scale, &bias, lane, &mut planes_ref, n_limbs);
        let mut acc_ref = vec![0.5f32; n * n_out];
        generic.popcount_rows(&limbs, n, &row, &mut acc_ref, n_out);

        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<u32>>();
        for backend in simd::available_backends() {
            let kern = backend.kernels();
            let bn = backend.name();
            let mut z = vec![f32::NAN; n_out];
            kern.gemm_zero_skip(&img, &w, n_out, &mut z);
            assert_eq!(bits(&z), bits(&z_ref), "gemm_zero_skip simd:{bn}");
            let mut planes = vec![0u64; n_out * n_limbs];
            kern.sign_planes(&z, &scale, &bias, lane, &mut planes, n_limbs);
            assert_eq!(planes, planes_ref, "sign_planes simd:{bn}");
            let mut acc = vec![0.5f32; n * n_out];
            kern.popcount_rows(&limbs, n, &row, &mut acc, n_out);
            assert_eq!(bits(&acc), bits(&acc_ref), "popcount_rows simd:{bn}");
        }
    });
}

#[test]
fn simd_selection_honors_override_and_falls_back() {
    // Every available backend is selectable by name, case- and
    // whitespace-insensitively (the NULLANET_SIMD_BACKEND parse path);
    // unknown names fall back to detection; the selected backend is
    // always one this CPU can actually execute.
    for backend in simd::available_backends() {
        let name = backend.name();
        assert_eq!(simd::select_from(Some(name)), backend);
        assert_eq!(simd::select_from(Some(&name.to_uppercase())), backend);
        assert_eq!(simd::select_from(Some(&format!("  {name} "))), backend);
    }
    assert_eq!(simd::select_from(Some("generic")), simd::Backend::Generic);
    assert_eq!(simd::select_from(None), simd::detect());
    assert_eq!(simd::select_from(Some("")), simd::detect());
    assert_eq!(simd::select_from(Some("quantum")), simd::detect());
    assert!(simd::select().available(), "selected backend must be executable");
}

#[test]
fn wide_eval_batch_agrees_with_scalar_eval() {
    check("wide-batch-equals-scalar", 15, |rng| {
        let n = rng.range(2, 9);
        let mut g = Aig::new(n);
        let mut lits: Vec<Lit> = (0..n).map(|i| g.pi(i)).collect();
        for _ in 0..rng.range(1, 60) {
            let a = lits[rng.range(0, lits.len())];
            let b = lits[rng.range(0, lits.len())];
            lits.push(g.and(
                if rng.bool(0.5) { a.not() } else { a },
                if rng.bool(0.5) { b.not() } else { b },
            ));
        }
        g.add_output(*lits.last().unwrap());
        let tape = LogicTape::from_aig(&g);
        let rows: Vec<Vec<bool>> = (0..rng.range(65, 512))
            .map(|_| (0..n).map(|_| rng.bool(0.5)).collect())
            .collect();
        let wide = tape.eval_batch_wide::<W512>(&rows);
        for (row, out) in rows.iter().zip(wide) {
            assert_eq!(out, g.eval(row));
        }
    });
}

#[test]
fn aig_passes_preserve_signatures() {
    check("aig-passes-preserve", 20, |rng| {
        let n = rng.range(3, 9);
        let mut g = Aig::new(n);
        let mut lits: Vec<Lit> = (0..n).map(|i| g.pi(i)).collect();
        for _ in 0..rng.range(5, 120) {
            let a = lits[rng.range(0, lits.len())];
            let b = lits[rng.range(0, lits.len())];
            lits.push(g.and(
                if rng.bool(0.5) { a.not() } else { a },
                if rng.bool(0.5) { b.not() } else { b },
            ));
        }
        for _ in 0..3 {
            let o = lits[rng.range(0, lits.len())];
            g.add_output(if rng.bool(0.5) { o.not() } else { o });
        }
        let sig = aig::random_signature(&g, 11, 8);
        let b = aig::balance(&g);
        assert_eq!(aig::random_signature(&b, 11, 8), sig, "balance changed function");
        let r = aig::rewrite(&g, &aig::RewriteConfig::default());
        assert_eq!(aig::random_signature(&r, 11, 8), sig, "rewrite changed function");
        let rf = aig::refactor(&g, &aig::RefactorConfig::default());
        assert_eq!(aig::random_signature(&rf, 11, 8), sig, "refactor changed function");
    });
}

#[test]
fn isop_within_bounds_random() {
    check("isop-bounds", 40, |rng| {
        let n = rng.range(1, 8);
        let l = TruthTable::from_fn(n, |_| rng.bool(0.3));
        let dc = TruthTable::from_fn(n, |_| rng.bool(0.4));
        let u = l.or(&dc);
        let cover = l.isop(&u);
        let g = TruthTable::from_cover(&cover);
        assert!(l.and(&g.not()).is_zero());
        assert!(g.and(&u.not()).is_zero());
    });
}

#[test]
fn f16_conversion_roundtrip_prop() {
    check("f16-roundtrip", 100, |rng| {
        let bits = (rng.next_u64() & 0xffff) as u16;
        let h = nullanet::arith::F16(bits);
        let f = h.to_f32();
        if !f.is_nan() {
            assert_eq!(nullanet::arith::F16::from_f32(f).0, h.0);
        }
    });
}

#[test]
fn verifier_agrees_with_from_parts_on_arbitrary_tapes() {
    // The static verifier strictly subsumes the constructor: for ANY
    // raw parts — mostly invalid here — `LogicTape::from_parts`
    // succeeds iff `verify_tape_parts` reports zero errors (semantic
    // warnings never block construction).  This is the guarantee that
    // lets the loader verify *before* building: nothing the verifier
    // passes can make `from_parts` fail, and nothing it rejects is
    // ever constructed.
    check("verify-agrees-from-parts", 200, |rng| {
        let n_inputs = rng.range(1, 10);
        let base = n_inputs + 1;
        let n_ops = rng.range(0, 40);
        let total = base + n_ops;
        fn mask(rng: &mut SplitMix64) -> u64 {
            match rng.range(0, 4) {
                0 => 0,
                1 => !0,
                2 => rng.next_u64(),
                _ => 1, // guaranteed non-broadcast
            }
        }
        let ops: Vec<TapeOp> = (0..n_ops)
            .map(|_| TapeOp {
                a: rng.range(0, total + 3) as u32,
                b: rng.range(0, total + 3) as u32,
                ca: mask(rng),
                cb: mask(rng),
            })
            .collect();
        let outputs: Vec<(u32, u64)> = (0..rng.range(0, 4))
            .map(|_| (rng.range(0, total + 3) as u32, mask(rng)))
            .collect();
        let report = verify::verify_tape_parts(n_inputs, &ops, &outputs);
        let built = LogicTape::from_parts(n_inputs, ops, outputs);
        assert_eq!(
            report.ok(),
            built.is_ok(),
            "verifier and constructor disagree:\n{report}"
        );
    });
}

#[test]
fn seeded_tape_defects_get_the_matching_stable_code() {
    // Start from a provably clean random tape, seed exactly one defect
    // of a random class, and the verifier must report that class's
    // stable NL code — and the constructor must reject the same parts.
    check("verify-seeded-defects", 120, |rng| {
        let n_inputs = rng.range(2, 10);
        let base = n_inputs + 1;
        let n_ops = rng.range(2, 50);
        let total = base + n_ops;
        fn bit(rng: &mut SplitMix64) -> u64 {
            if rng.bool(0.5) { 0 } else { !0 }
        }
        let mut ops: Vec<TapeOp> = (0..n_ops)
            .map(|i| {
                let limit = base + i;
                TapeOp {
                    a: rng.range(1, limit) as u32,
                    b: rng.range(1, limit) as u32,
                    ca: bit(rng),
                    cb: bit(rng),
                }
            })
            .collect();
        let mut outputs: Vec<(u32, u64)> = (0..rng.range(1, 4))
            .map(|_| (rng.range(1, total) as u32, bit(rng)))
            .collect();
        let clean = verify::verify_tape_parts(n_inputs, &ops, &outputs);
        assert_eq!(clean.n_errors(), 0, "generator seeded a defect:\n{clean}");

        let bad_mask = {
            let mut m = rng.next_u64();
            while m == 0 || m == !0 {
                m = rng.next_u64();
            }
            m
        };
        let want = match rng.range(0, 5) {
            0 => {
                // Forward reference: read a plane at or past this op's
                // own destination.
                let i = rng.range(0, n_ops);
                ops[i].a = rng.range(base + i, total) as u32;
                code::FANIN_FORWARD
            }
            1 => {
                ops[rng.range(0, n_ops)].b = (total + rng.range(0, 9)) as u32;
                code::FANIN_RANGE
            }
            2 => {
                ops[rng.range(0, n_ops)].ca = bad_mask;
                code::OP_MASK
            }
            3 => {
                outputs[0].0 = (total + rng.range(0, 9)) as u32;
                code::OUTPUT_RANGE
            }
            _ => {
                outputs[0].1 = bad_mask;
                code::OUTPUT_MASK
            }
        };
        let report = verify::verify_tape_parts(n_inputs, &ops, &outputs);
        assert!(!report.ok(), "seeded {want}, verifier saw nothing");
        assert!(report.has(want), "seeded {want}, got:\n{report}");
        assert!(
            LogicTape::from_parts(n_inputs, ops, outputs).is_err(),
            "constructor accepted a tape the verifier rejects ({want})"
        );
    });
}

#[test]
fn pipeline_tapes_and_schedules_verify_clean() {
    // Every tape the synthesis pipeline emits — and the liveness
    // schedule the engine derives from it — must pass the static
    // verifier with zero errors (dead-cone warnings are fine:
    // `from_aig` keeps dead ops, the scheduler strips them).
    check("verify-clean-pipeline", 40, |rng| {
        let n = rng.range(2, 10);
        let mut g = Aig::new(n);
        let mut lits: Vec<Lit> = (0..n).map(|i| g.pi(i)).collect();
        for _ in 0..rng.range(1, 100) {
            let a = lits[rng.range(0, lits.len())];
            let b = lits[rng.range(0, lits.len())];
            lits.push(g.and(
                if rng.bool(0.5) { a.not() } else { a },
                if rng.bool(0.5) { b.not() } else { b },
            ));
        }
        for _ in 0..rng.range(1, 4) {
            let o = lits[rng.range(0, lits.len())];
            g.add_output(if rng.bool(0.5) { o.not() } else { o });
        }
        let tape = LogicTape::from_aig(&g);
        let report = verify::verify_tape_and_schedule(&tape);
        assert_eq!(report.n_errors(), 0, "{report}");
    });
}

#[test]
fn lutmap_preserves_function_prop() {
    check("lutmap-preserves", 20, |rng| {
        let n = rng.range(2, 9);
        let mut g = Aig::new(n);
        let mut lits: Vec<Lit> = (0..n).map(|i| g.pi(i)).collect();
        for _ in 0..rng.range(2, 100) {
            let a = lits[rng.range(0, lits.len())];
            let b = lits[rng.range(0, lits.len())];
            lits.push(g.and(
                if rng.bool(0.5) { a.not() } else { a },
                if rng.bool(0.5) { b.not() } else { b },
            ));
        }
        g.add_output(*lits.last().unwrap());
        let m = nullanet::lutmap::map_luts(&g, &nullanet::lutmap::LutMapConfig::default());
        for _ in 0..20 {
            let ins: Vec<bool> = (0..n).map(|_| rng.bool(0.5)).collect();
            assert_eq!(nullanet::lutmap::eval_mapping(&g, &m, &ins), g.eval(&ins));
        }
    });
}
