//! Mutation tests for the static artifact verifier: splice or bit-flip
//! every `.nnc` section (header, layer, param, footer, tape ops) and
//! assert that `verify_artifact` reports the *right* stable `NL***`
//! code — `NL021` wherever a digest catches the damage, `NL020` for
//! structural failures (bad magic, version, truncation), and dead-cone
//! warnings (`NL006`) on artifacts that are damaged only in spirit.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use nullanet::aig::{Aig, Lit};
use nullanet::artifact::{verify_artifact, CompiledLayer, CompiledModel, LayerStats};
use nullanet::model::Arch;
use nullanet::netlist::verify::code;
use nullanet::netlist::{LogicTape, TapeOp};
use nullanet::util::SplitMix64;

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("nullanet_verify_mut_{tag}"));
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn random_tape(rng: &mut SplitMix64, n_pis: usize, n_ands: usize, n_outs: usize) -> LogicTape {
    let mut g = Aig::new(n_pis);
    let mut lits: Vec<Lit> = (0..n_pis).map(|i| g.pi(i)).collect();
    for _ in 0..n_ands {
        let a = lits[rng.range(0, lits.len())];
        let b = lits[rng.range(0, lits.len())];
        let a = if rng.bool(0.5) { a.not() } else { a };
        let b = if rng.bool(0.5) { b.not() } else { b };
        lits.push(g.and(a, b));
    }
    for _ in 0..n_outs {
        let o = lits[rng.range(0, lits.len())];
        g.add_output(if rng.bool(0.5) { o.not() } else { o });
    }
    LogicTape::from_aig(&g)
}

fn model_with(name: &str, tapes: Vec<LogicTape>) -> CompiledModel {
    let n = tapes[0].n_inputs;
    CompiledModel {
        name: name.into(),
        arch: Arch::Mlp { sizes: vec![n, n, n, n] },
        accuracy_test: f64::NAN,
        layers: tapes
            .into_iter()
            .enumerate()
            .map(|(i, tape)| CompiledLayer {
                name: format!("layer{}", i + 2),
                tape,
                stats: LayerStats { n_distinct: 1 + i, ..Default::default() },
            })
            .collect(),
        params: BTreeMap::new(),
        provenance: None,
    }
}

/// Save a one-layer model (with one parameter tensor so the param
/// section exists) and return (path, file text).
fn saved_artifact(dir: &Path, file: &str, seed: u64) -> (PathBuf, String) {
    let mut rng = SplitMix64::new(seed);
    let tape = random_tape(&mut rng, 5, 40, 3);
    let mut cm = model_with("mut", vec![tape]);
    cm.params.insert(
        "w1".to_string(),
        nullanet::model::Tensor { shape: vec![2, 2], f32s: vec![1.0, 0.5, -0.25, 0.0] },
    );
    let path = dir.join(file);
    cm.save(&path).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    (path, text)
}

#[test]
fn clean_artifact_verifies_ok() {
    let dir = tmpdir("clean");
    let (path, _) = saved_artifact(&dir, "ok.nnc", 1);
    let report = verify_artifact(&path);
    assert!(report.ok(), "{report}");
    assert_eq!(report.n_errors(), 0);
}

#[test]
fn every_section_mutation_yields_the_right_code() {
    let dir = tmpdir("sections");
    let (_, text) = saved_artifact(&dir, "base.nnc", 2);
    // (what is damaged, how, which code must come back)
    let cases: Vec<(&str, Box<dyn Fn(&str) -> String>, &str)> = vec![
        (
            "header model name (footer chain catches it)",
            Box::new(|t: &str| t.replacen("\"name\":\"mut\"", "\"name\":\"evil\"", 1)),
            code::ARTIFACT_DIGEST,
        ),
        (
            "header version (rejected before any digest)",
            Box::new(|t: &str| t.replacen("\"version\":1", "\"version\":99", 1)),
            code::ARTIFACT_STRUCTURE,
        ),
        (
            "header magic",
            Box::new(|t: &str| t.replacen("\"magic\":\"", "\"magic\":\"x", 1)),
            code::ARTIFACT_STRUCTURE,
        ),
        (
            // The layer digest covers the name, tape ops, and stats; a
            // renamed layer decodes fine but can't match its digest.
            "layer section content (section digest catches it)",
            Box::new(|t: &str| t.replacen("\"name\":\"layer2\"", "\"name\":\"layerX\"", 1)),
            code::ARTIFACT_DIGEST,
        ),
        (
            "param section content (section digest catches it)",
            Box::new(|t: &str| t.replacen("\"name\":\"w1\"", "\"name\":\"wX\"", 1)),
            code::ARTIFACT_DIGEST,
        ),
        (
            "footer chain digest",
            Box::new(|t: &str| {
                let at = t.rfind("\"digest\":\"").unwrap() + "\"digest\":\"".len();
                let mut s = t.to_string();
                let old = s.as_bytes()[at];
                let new = if old == b'0' { b'1' } else { b'0' };
                // Replace the first hex char of the footer digest.
                s.replace_range(at..at + 1, std::str::from_utf8(&[new]).unwrap());
                s
            }),
            code::ARTIFACT_DIGEST,
        ),
        (
            "footer removed entirely (truncation)",
            Box::new(|t: &str| t[..t.rfind("{\"digest\"").unwrap()].to_string()),
            code::ARTIFACT_STRUCTURE,
        ),
    ];
    let bad = dir.join("bad.nnc");
    for (what, mutate, want_code) in cases {
        let mutated = mutate(&text);
        assert_ne!(mutated, text, "mutation for {what} was a no-op");
        std::fs::write(&bad, &mutated).unwrap();
        let report = verify_artifact(&bad);
        assert!(!report.ok(), "{what}: damaged artifact verified clean");
        assert!(
            report.has(want_code),
            "{what}: expected {want_code}, got:\n{report}"
        );
    }
}

#[test]
fn tape_op_rewiring_is_caught_by_the_layer_digest() {
    let dir = tmpdir("opswap");
    // Known tape so the serialized op is exactly [1,2,0,0]: plane 3 =
    // p1 & p2, output plane 3.
    let tape = LogicTape::from_parts(2, vec![TapeOp { a: 1, b: 2, ca: 0, cb: 0 }], vec![(3, 0)])
        .unwrap();
    let cm = model_with("opswap", vec![tape]);
    let path = dir.join("ok.nnc");
    cm.save(&path).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    assert!(text.contains("\"ops\":[[1,2,0,0]]"), "{text}");
    // Rewire fanin a: 1 -> 2.  Still a structurally valid tape (plane 2
    // is input b), so only the layer digest can tell it is not the tape
    // that was compiled.
    let bad = dir.join("bad.nnc");
    std::fs::write(&bad, text.replacen("\"ops\":[[1,2,0,0]]", "\"ops\":[[2,2,0,0]]", 1)).unwrap();
    let report = verify_artifact(&bad);
    assert!(!report.ok(), "rewired tape verified clean:\n{report}");
    assert!(report.has(code::ARTIFACT_DIGEST), "{report}");
}

#[test]
fn spliced_layer_section_is_rejected_by_the_chain_digest() {
    let dir = tmpdir("splice");
    let (_, text_a) = saved_artifact(&dir, "a.nnc", 3);
    let (_, text_b) = saved_artifact(&dir, "b.nnc", 4);
    let layer_of = |t: &str| {
        t.lines()
            .find(|l| l.contains("\"section\":\"layer\""))
            .unwrap()
            .to_string()
    };
    let (la, lb) = (layer_of(&text_a), layer_of(&text_b));
    assert_ne!(la, lb, "seeds produced identical layers");
    // Each spliced line has a self-consistent section digest; only the
    // footer chain digest can catch the cross-file transplant.
    let spliced = text_b.replacen(&lb, &la, 1);
    let bad = dir.join("spliced.nnc");
    std::fs::write(&bad, spliced).unwrap();
    let report = verify_artifact(&bad);
    assert!(!report.ok(), "spliced artifact verified clean:\n{report}");
    assert!(report.has(code::ARTIFACT_DIGEST), "{report}");
}

#[test]
fn random_bit_flips_are_never_accepted() {
    let dir = tmpdir("bitflip");
    let (_, text) = saved_artifact(&dir, "base.nnc", 5);
    let bytes = text.as_bytes();
    let bad = dir.join("flipped.nnc");
    let mut rng = SplitMix64::new(99);
    for case in 0..60 {
        let pos = rng.range(0, bytes.len());
        let bit = rng.range(0, 8) as u32;
        let mut mutated = bytes.to_vec();
        mutated[pos] ^= 1 << bit;
        // Flipping a newline can only merge/split lines; anything else
        // changes section content.  Either way the verifier must object.
        std::fs::write(&bad, &mutated).unwrap();
        let report = verify_artifact(&bad);
        assert!(
            !report.ok(),
            "case {case}: flip of bit {bit} at byte {pos} (0x{:02x}) accepted",
            bytes[pos]
        );
        let coded = report.has(code::ARTIFACT_DIGEST) || report.has(code::ARTIFACT_STRUCTURE);
        assert!(coded, "case {case}: error without a stable NL code:\n{report}");
    }
}

#[test]
fn dead_cone_in_a_loadable_artifact_is_a_warning_not_an_error() {
    let dir = tmpdir("deadcone");
    // Hand-build a tape with an op outside every output cone: plane 3 =
    // p1&p2 (live, output), plane 4 = p1&p1 (dead).
    let tape = LogicTape::from_parts(
        2,
        vec![TapeOp { a: 1, b: 2, ca: 0, cb: 0 }, TapeOp { a: 1, b: 1, ca: 0, cb: 0 }],
        vec![(3, 0)],
    )
    .unwrap();
    let cm = model_with("deadcone", vec![tape]);
    let path = dir.join("dead.nnc");
    cm.save(&path).unwrap();
    let report = verify_artifact(&path);
    assert!(report.ok(), "warnings must not fail verification:\n{report}");
    assert!(report.has(code::DEAD_CONE), "{report}");
    assert_eq!(report.n_warnings(), 1, "{report}");
}
