//! Integration: coordinator + server over a real synthesized engine.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;

use nullanet::coordinator::{engine::InferenceEngine, Coordinator, CoordinatorConfig};
use nullanet::registry::{ModelMeta, ModelRegistry};
use nullanet::server::Server;

/// Deterministic stand-in engine: class = round(sum) % 10.
struct SumEngine;

impl InferenceEngine for SumEngine {
    fn infer_batch(&self, images: &[&[f32]]) -> Vec<Vec<f32>> {
        images
            .iter()
            .map(|img| {
                let mut l = vec![0f32; 10];
                l[(img.iter().sum::<f32>().round() as usize) % 10] = 1.0;
                l
            })
            .collect()
    }
    fn name(&self) -> &str {
        "sum"
    }
}

#[test]
fn no_request_lost_under_concurrency() {
    let coord = Arc::new(Coordinator::start(
        Arc::new(SumEngine),
        CoordinatorConfig {
            workers: 2,
            max_wait: Duration::from_millis(1),
            ..Default::default()
        },
    ));
    let mut handles = vec![];
    for t in 0..6 {
        let c = Arc::clone(&coord);
        handles.push(std::thread::spawn(move || {
            let mut ok = 0;
            for i in 0..200 {
                let v = ((t + i) % 10) as f32;
                let r = c.infer(vec![v]).unwrap();
                assert_eq!(r.class, v as usize);
                ok += 1;
            }
            ok
        }));
    }
    let total: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
    assert_eq!(total, 1200);
    assert_eq!(coord.metrics.requests(), 1200);
    // Batching must have occurred under this load.
    assert!(coord.metrics.mean_batch_size() >= 1.0);
}

#[test]
fn responses_match_requests_not_reordered_within_stream() {
    let coord = Coordinator::start(Arc::new(SumEngine), CoordinatorConfig::default());
    let mut rxs = vec![];
    for i in 0..100 {
        rxs.push((i, coord.submit(vec![(i % 10) as f32]).unwrap()));
    }
    for (i, rx) in rxs {
        let r = rx.recv().unwrap();
        assert_eq!(r.class, i % 10, "response for request {i} wrong");
    }
    coord.shutdown();
}

#[test]
fn server_concurrent_clients() {
    let registry = Arc::new(ModelRegistry::new(CoordinatorConfig::default(), 64));
    let eng = Arc::new(SumEngine);
    registry
        .register(ModelMeta::for_engine("sum", eng.as_ref(), 64), eng)
        .unwrap();
    let srv = Server::start("127.0.0.1:0", Arc::clone(&registry)).unwrap();
    let addr = srv.addr;
    let mut handles = vec![];
    for t in 0..4 {
        handles.push(std::thread::spawn(move || {
            let mut conn = TcpStream::connect(addr).unwrap();
            let mut reader = BufReader::new(conn.try_clone().unwrap());
            for i in 0..50 {
                let v = (t * 50 + i) % 10;
                conn.write_all(format!("{{\"image\": [{v}]}}\n").as_bytes())
                    .unwrap();
                let mut line = String::new();
                reader.read_line(&mut line).unwrap();
                assert!(
                    line.contains(&format!("\"class\":{v}")),
                    "client {t} req {i}: {line}"
                );
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let entry = registry.get(Some("sum")).unwrap();
    assert_eq!(entry.coordinator.metrics.requests(), 200);
    srv.shutdown();
}

#[test]
fn thousand_request_batch_shards_across_workers_in_order() {
    use std::collections::HashSet;
    use std::sync::Mutex;
    use std::thread::ThreadId;

    /// SumEngine that records which worker threads executed blocks and
    /// enforces the engine's preferred block width.
    struct ShardProbe {
        threads: Mutex<HashSet<ThreadId>>,
        max_block: usize,
    }

    impl InferenceEngine for ShardProbe {
        fn infer_batch(&self, images: &[&[f32]]) -> Vec<Vec<f32>> {
            assert!(
                images.len() <= self.max_block,
                "block of {} exceeds preferred width {}",
                images.len(),
                self.max_block
            );
            self.threads.lock().unwrap().insert(std::thread::current().id());
            // Slow the block down slightly so blocks overlap in time and
            // the pool genuinely runs them concurrently.
            std::thread::sleep(Duration::from_micros(500));
            SumEngine.infer_batch(images)
        }
        fn name(&self) -> &str {
            "shard-probe"
        }
        fn preferred_block(&self) -> usize {
            self.max_block
        }
    }

    let probe = Arc::new(ShardProbe {
        threads: Mutex::new(HashSet::new()),
        max_block: 32,
    });
    let coord = Arc::new(Coordinator::start(
        Arc::clone(&probe) as Arc<dyn InferenceEngine>,
        CoordinatorConfig {
            workers: 4,
            max_batch: 512,
            max_wait: Duration::from_millis(5),
            ..Default::default()
        },
    ));

    // One big wave of requests, receivers kept in submission order.
    let n = 1000usize;
    let mut rxs = Vec::with_capacity(n);
    for i in 0..n {
        rxs.push((i, coord.submit(vec![(i % 10) as f32]).unwrap()));
    }
    let mut last_id = None;
    for (i, rx) in rxs {
        let r = rx.recv().unwrap();
        // Reassembly: response i answers request i...
        assert_eq!(r.class, i % 10, "response for request {i} wrong");
        // ...and ids are handed out in submission order.
        assert_eq!(r.id, i as u64);
        if let Some(prev) = last_id {
            assert!(r.id > prev);
        }
        last_id = Some(r.id);
    }
    assert_eq!(coord.metrics.requests(), n as u64);

    // 1000 requests at block width 32 → at least 32 blocks executed.
    assert!(coord.metrics.batches() >= 32, "blocks: {}", coord.metrics.batches());
    // The blocks must have been spread over the pool, not serialized on
    // one worker.
    let distinct = probe.threads.lock().unwrap().len();
    assert!(distinct >= 2, "expected ≥2 workers to run blocks, saw {distinct}");

    let coord = Arc::try_unwrap(coord).ok().expect("sole owner");
    coord.shutdown();
}

#[test]
fn queue_backpressure_does_not_deadlock() {
    let coord = Arc::new(Coordinator::start(
        Arc::new(SumEngine),
        CoordinatorConfig {
            queue_depth: 4,
            workers: 1,
            max_wait: Duration::from_micros(100),
            ..Default::default()
        },
    ));
    // Many more submissions than queue depth from several threads.
    let mut handles = vec![];
    for _ in 0..4 {
        let c = Arc::clone(&coord);
        handles.push(std::thread::spawn(move || {
            for i in 0..100 {
                let _ = c.infer(vec![(i % 10) as f32]).unwrap();
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    assert_eq!(coord.metrics.requests(), 400);
}
