//! Integration: Algorithm 2 end-to-end on threshold-function layers —
//! the synthesized tape must agree with Eq. 1 on every observed pattern
//! and generalize sensibly on unseen ones.

use nullanet::isf::{extract, IsfConfig, LayerObservations};
use nullanet::model::ThresholdLayer;
use nullanet::synth::{optimize_layer, verify_layer, SynthConfig};
use nullanet::util::{BitVec, SplitMix64};

fn threshold_layer(rng: &mut SplitMix64, n_in: usize, n_out: usize) -> ThresholdLayer {
    ThresholdLayer {
        n_in,
        n_out,
        w: (0..n_in * n_out).map(|_| rng.normal() as f32).collect(),
        theta: (0..n_out).map(|_| rng.normal() as f32).collect(),
        flip: (0..n_out).map(|_| rng.bool(0.2)).collect(),
    }
}

fn observe(layer: &ThresholdLayer, rng: &mut SplitMix64, n_samples: usize) -> LayerObservations {
    let in_stride = (layer.n_in + 7) / 8;
    let out_stride = (layer.n_out + 7) / 8;
    let mut inputs = vec![0u8; n_samples * in_stride];
    let mut outputs = vec![0u8; n_samples * out_stride];
    for s in 0..n_samples {
        let bits = BitVec::from_bools((0..layer.n_in).map(|_| rng.bool(0.5)));
        for i in bits.iter_ones() {
            inputs[s * in_stride + i / 8] |= 1 << (i % 8);
        }
        let out = layer.eval(&bits);
        for j in out.iter_ones() {
            outputs[s * out_stride + j / 8] |= 1 << (j % 8);
        }
    }
    LayerObservations {
        name: "thr".into(),
        n_in: layer.n_in,
        n_out: layer.n_out,
        inputs,
        outputs,
        n_samples,
    }
}

#[test]
fn synthesized_layer_is_exact_on_observations() {
    let mut rng = SplitMix64::new(10);
    let layer = threshold_layer(&mut rng, 24, 12);
    let obs = observe(&layer, &mut rng, 1500);
    let isf = extract(&obs, &IsfConfig::default());
    assert_eq!(isf.n_conflicts, 0, "threshold functions are consistent");
    let s = optimize_layer("thr", &isf, &SynthConfig::default());
    assert_eq!(verify_layer(&isf, &s), 0);
}

#[test]
fn synthesized_layer_generalizes_to_unseen_patterns() {
    // The DC-set assignment should track the threshold function on most
    // unseen inputs (the paper's "close to ON-set" argument).
    let mut rng = SplitMix64::new(11);
    let layer = threshold_layer(&mut rng, 20, 8);
    let obs = observe(&layer, &mut rng, 4000);
    let isf = extract(&obs, &IsfConfig::default());
    let s = optimize_layer("thr", &isf, &SynthConfig::default());
    assert_eq!(verify_layer(&isf, &s), 0);

    let mut agree = 0usize;
    let total = 2000usize;
    let mut scratch = s.tape.make_scratch();
    for _ in 0..total {
        let bits = BitVec::from_bools((0..layer.n_in).map(|_| rng.bool(0.5)));
        let want = layer.eval(&bits);
        let row: Vec<bool> = (0..layer.n_in).map(|v| bits.get(v)).collect();
        let mut inputs = vec![0u64; layer.n_in];
        for (i, &b) in row.iter().enumerate() {
            if b {
                inputs[i] = 1;
            }
        }
        let mut out = vec![0u64; layer.n_out];
        s.tape.eval_into(&inputs, &mut out, &mut scratch);
        for j in 0..layer.n_out {
            if (out[j] & 1 == 1) == want.get(j) {
                agree += 1;
            }
        }
    }
    let frac = agree as f64 / (total * layer.n_out) as f64;
    // 4000 of 2^20 possible patterns observed: mid-80s-to-90s agreement
    // on uniform unseen inputs is the expected regime (see EXPERIMENTS.md).
    assert!(frac > 0.8, "generalization too weak: {frac}");
}

#[test]
fn pipeline_plan_over_synthesized_layers() {
    let mut rng = SplitMix64::new(12);
    let fpga = nullanet::cost::FpgaModel::default();
    let mut costs = vec![];
    for _ in 0..3 {
        let layer = threshold_layer(&mut rng, 16, 8);
        let obs = observe(&layer, &mut rng, 800);
        let isf = extract(&obs, &IsfConfig::default());
        let s = optimize_layer("thr", &isf, &SynthConfig::default());
        costs.push(s.hw_cost(&fpga));
    }
    let plan = nullanet::pipeline::one_stage_per_layer(&fpga, &costs);
    assert_eq!(plan.stages.len(), 3);
    assert!(plan.period_ns >= costs.iter().map(|c| c.latency_ns).fold(0.0, f64::max) - 1e-9);
    assert!(plan.throughput_hz > 0.0);
}

#[test]
fn codegen_compiles_semantics() {
    // Pythonize(): generated source must textually encode the same ops.
    let mut rng = SplitMix64::new(13);
    let layer = threshold_layer(&mut rng, 10, 4);
    let obs = observe(&layer, &mut rng, 400);
    let isf = extract(&obs, &IsfConfig::default());
    let s = optimize_layer("thr", &isf, &SynthConfig::default());
    let src = nullanet::netlist::tape_to_rust_source(&s.tape, "thr_layer");
    assert!(src.contains("pub fn thr_layer(inputs: &[u64; 10]) -> [u64; 4]"));
    assert!(src.matches('&').count() >= s.tape.n_ops());
}
