//! v1 → v2 wire compatibility: a recorded v1 session replayed against
//! the v2 server must produce byte-equivalent replies.
//!
//! The "recording" is a frozen copy of the protocol-v1 request handler
//! (`v1_reply`, transcribed from the pre-registry `server.rs`) run
//! against the same engine: for every v1 request line, the bytes the v2
//! server sends over TCP must equal the bytes v1 would have produced.
//! The only normalization is the `queue_us` timing counter, which is
//! nondeterministic by nature; every other byte — key set, key order,
//! number formatting, error strings — must match exactly.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;

use nullanet::coordinator::{engine::InferenceEngine, Coordinator, CoordinatorConfig};
use nullanet::jsonio::{num, obj, Json};
use nullanet::registry::{ModelMeta, ModelRegistry};
use nullanet::server::Server;

/// Deterministic engine: class = sum(image) % 10 (the same stand-in the
/// v1 server tests used).
struct SumEngine;

impl InferenceEngine for SumEngine {
    fn infer_batch(&self, images: &[&[f32]]) -> Vec<Vec<f32>> {
        images
            .iter()
            .map(|img| {
                let mut l = vec![0.0; 10];
                l[img.iter().sum::<f32>() as usize % 10] = 1.0;
                l
            })
            .collect()
    }
    fn name(&self) -> &str {
        "sum"
    }
    fn input_dim(&self) -> Option<usize> {
        Some(2)
    }
}

/// The recorded v1 session: the v1 request shapes with byte-stable
/// replies (inference, ping, and every error path).  `info` and
/// `metrics` are deliberately absent: their v2 replies are supersets of
/// v1 (new keys added, no v1 key changed), which
/// `v1_info_and_metrics_keys_survive_as_supersets` below holds instead.
const V1_SESSION: &[&str] = &[
    "{\"cmd\": \"ping\"}",
    "{\"cmd\": \"bogus\"}",
    "not json",
    "{\"image\": [1.0, \"x\"]}",
    "{\"image\": [2.0, 3.0]}",
    "{\"image\": [1.0]}",
    "{}",
    "{\"image\": [9.0, 9.0]}",
];

// ---------------------------------------------------------------------
// Frozen v1 handler (transcribed from the pre-registry server.rs).
// ---------------------------------------------------------------------

fn v1_reply(line: &str, coord: &Coordinator, input_dim: Option<usize>) -> String {
    let reply = match v1_handle(line, coord, input_dim) {
        Ok(j) => j,
        Err(e) => obj(vec![("error", Json::Str(e))]),
    };
    reply.to_string()
}

fn v1_handle(
    line: &str,
    coord: &Coordinator,
    input_dim: Option<usize>,
) -> Result<Json, String> {
    let j = Json::parse(line).map_err(|e| format!("bad json: {e}"))?;
    if let Some(cmd) = j.get("cmd").and_then(Json::as_str) {
        return Ok(match cmd {
            "ping" => obj(vec![("ok", Json::Bool(true))]),
            other => obj(vec![("error", Json::Str(format!("unknown cmd {other}")))]),
        });
    }
    let img = j
        .get("image")
        .and_then(Json::as_arr)
        .ok_or_else(|| "missing image (or unknown request shape)".to_string())?;
    let mut image = Vec::with_capacity(img.len());
    for v in img {
        match v.as_f64() {
            Some(f) => image.push(f as f32),
            None => return Err("image must be an array of numbers".to_string()),
        }
    }
    if let Some(dim) = input_dim {
        if image.len() != dim {
            return Err(format!("image has {} values, expected {dim}", image.len()));
        }
    }
    let resp = coord.infer(image).map_err(|e| e.to_string())?;
    Ok(obj(vec![
        ("class", num(resp.class as f64)),
        (
            "logits",
            Json::Arr(resp.logits.iter().map(|&l| num(l as f64)).collect()),
        ),
        ("queue_us", num(resp.queue_us as f64)),
        ("batch", num(resp.batch_size as f64)),
    ]))
}

/// Zero out the digits after `"queue_us":` — the one nondeterministic
/// field in a v1 reply.
fn normalize(line: &str) -> String {
    let key = "\"queue_us\":";
    let Some(start) = line.find(key) else {
        return line.to_string();
    };
    let digits_from = start + key.len();
    let digits_len = line[digits_from..]
        .bytes()
        .take_while(|b| b.is_ascii_digit())
        .count();
    format!("{}0{}", &line[..digits_from], &line[digits_from + digits_len..])
}

#[test]
fn v1_session_replay_is_byte_equivalent() {
    // Reference: the frozen v1 handler over its own coordinator.
    let v1_coord = Coordinator::start(Arc::new(SumEngine), CoordinatorConfig::default());
    let expected: Vec<String> = V1_SESSION
        .iter()
        .map(|line| normalize(&v1_reply(line, &v1_coord, Some(2))))
        .collect();

    // Live: the v2 server with the same engine as its default model.
    let registry = Arc::new(ModelRegistry::new(CoordinatorConfig::default(), 64));
    let eng = Arc::new(SumEngine);
    registry
        .register(ModelMeta::for_engine("sum", eng.as_ref(), 64), eng)
        .unwrap();
    let server = Server::start("127.0.0.1:0", Arc::clone(&registry)).unwrap();
    let mut conn = TcpStream::connect(server.addr).unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());

    for (line, want) in V1_SESSION.iter().zip(&expected) {
        conn.write_all(line.as_bytes()).unwrap();
        conn.write_all(b"\n").unwrap();
        let mut got = String::new();
        reader.read_line(&mut got).unwrap();
        let got = normalize(got.trim_end_matches('\n'));
        assert_eq!(&got, want, "v1 request {line:?}: v2 replied {got:?}, v1 said {want:?}");
        // The compat guarantee includes *not* growing new keys on v1
        // replies.
        assert!(!got.contains("\"id\""), "v1 reply grew an id: {got}");
    }

    drop(conn);
    server.shutdown();
    v1_coord.shutdown();
}

#[test]
fn v1_info_and_metrics_keys_survive_as_supersets() {
    let registry = Arc::new(ModelRegistry::new(CoordinatorConfig::default(), 64));
    let eng = Arc::new(SumEngine);
    registry
        .register(ModelMeta::for_engine("sum", eng.as_ref(), 64), eng)
        .unwrap();
    registry.get(None).unwrap().coordinator.infer(vec![1.0, 2.0]).unwrap();
    let server = Server::start("127.0.0.1:0", Arc::clone(&registry)).unwrap();
    let mut conn = TcpStream::connect(server.addr).unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    conn.write_all(b"{\"cmd\": \"info\"}\n{\"cmd\": \"metrics\"}\n").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let info = Json::parse(line.trim()).unwrap();
    // Every v1 info key is still present with its v1 meaning.
    assert_eq!(info.get("model").and_then(Json::as_str), Some("sum"));
    assert_eq!(info.get("engine").and_then(Json::as_str), Some("sum"));
    assert_eq!(info.get("width").and_then(Json::as_usize), Some(64));
    assert_eq!(info.get("source").and_then(Json::as_str), Some("synthesized"));
    assert_eq!(info.get("input_dim").and_then(Json::as_usize), Some(2));
    line.clear();
    reader.read_line(&mut line).unwrap();
    let metrics = Json::parse(line.trim()).unwrap();
    for key in ["requests", "blocks", "mean_block", "p50_us", "p99_us"] {
        assert!(metrics.get(key).is_some(), "v1 metrics key {key} missing: {metrics:?}");
    }
    assert_eq!(metrics.get("requests").and_then(Json::as_usize), Some(1));
    drop(conn);
    server.shutdown();
}

#[test]
fn v1_requests_route_to_default_model_among_many() {
    // A v1 client (no "model" field) on a multi-model server must hit
    // the default (first-registered) model.
    struct ConstEngine(usize);
    impl InferenceEngine for ConstEngine {
        fn infer_batch(&self, images: &[&[f32]]) -> Vec<Vec<f32>> {
            images
                .iter()
                .map(|_| {
                    let mut l = vec![0.0; 10];
                    l[self.0] = 1.0;
                    l
                })
                .collect()
        }
        fn name(&self) -> &str {
            "const"
        }
    }
    let registry = Arc::new(ModelRegistry::new(CoordinatorConfig::default(), 64));
    for (name, class) in [("first", 4usize), ("second", 6usize)] {
        let eng = Arc::new(ConstEngine(class));
        registry
            .register(ModelMeta::for_engine(name, eng.as_ref(), 64), eng)
            .unwrap();
    }
    let server = Server::start("127.0.0.1:0", Arc::clone(&registry)).unwrap();
    let mut conn = TcpStream::connect(server.addr).unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    conn.write_all(b"{\"image\": [0.0]}\n{\"model\": \"second\", \"image\": [0.0]}\n")
        .unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("\"class\":4"), "default model should answer: {line}");
    line.clear();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("\"class\":6"), "routed model should answer: {line}");
    drop(conn);
    server.shutdown();
}
