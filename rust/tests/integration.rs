//! Whole-system integration against real artifacts: Algorithm 2 over the
//! trained net, engine agreement, accuracy within tolerance of the
//! python-reported reference.  Skips politely if `make artifacts` hasn't
//! run.

use nullanet::coordinator::engine::{self, InferenceEngine};
use nullanet::{data, isf, model, synth};

fn artifacts() -> Option<model::Artifacts> {
    model::Artifacts::load(&nullanet::artifacts_dir()).ok()
}

#[test]
fn manifest_has_all_nets() {
    let Some(art) = artifacts() else {
        eprintln!("skipping: artifacts missing");
        return;
    };
    for n in ["net11", "net12", "net21", "net22"] {
        let net = art.net(n).unwrap();
        assert!(net.accuracy_test > 0.5, "{n}: {}", net.accuracy_test);
    }
    // Paper's ordering: ReLU nets beat sign nets of the same arch.
    assert!(art.net("net12").unwrap().accuracy_test > art.net("net11").unwrap().accuracy_test);
    assert!(art.net("net22").unwrap().accuracy_test > art.net("net21").unwrap().accuracy_test);
}

#[test]
fn threshold_engine_matches_python_accuracy() {
    // Net 1.1.a evaluated in rust (Eq. 1 bit domain) must reproduce the
    // python-reported accuracy almost exactly — this validates the whole
    // BN-folding + bit-domain-threshold interchange.
    let Some(art) = artifacts() else {
        eprintln!("skipping: artifacts missing");
        return;
    };
    let net = art.net("net11").unwrap().clone();
    let python_acc = net.accuracy_test;
    let ds = data::Dataset::load(&art.test_path).unwrap().take(2000);
    let eng = engine::ThresholdEngine::new(net).unwrap();
    let mut hits = 0;
    for start in (0..ds.n).step_by(256) {
        let end = (start + 256).min(ds.n);
        let images: Vec<&[f32]> = (start..end).map(|i| ds.image(i)).collect();
        for (k, l) in eng.infer_batch(&images).iter().enumerate() {
            if model::argmax(l) == ds.y[start + k] as usize {
                hits += 1;
            }
        }
    }
    let acc = hits as f64 / ds.n as f64;
    assert!(
        (acc - python_acc).abs() < 0.02,
        "rust {acc} vs python {python_acc}"
    );
}

#[test]
fn logic_engine_agrees_with_isf_on_training_patterns() {
    let Some(art) = artifacts() else {
        eprintln!("skipping: artifacts missing");
        return;
    };
    let net = art.net("net11").unwrap();
    let obs = isf::load_observations(&net.dir.join("activations.bin")).unwrap();
    let layer_isf = isf::extract(&obs[0], &isf::IsfConfig { max_patterns: 800 });
    let s = synth::optimize_layer("layer2", &layer_isf, &synth::SynthConfig::default());
    assert_eq!(synth::verify_layer(&layer_isf, &s), 0);
}

#[test]
fn logic_engine_close_to_threshold_engine_on_test_set() {
    let Some(art) = artifacts() else {
        eprintln!("skipping: artifacts missing");
        return;
    };
    let net = art.net("net11").unwrap().clone();
    let ds = data::Dataset::load(&art.test_path).unwrap().take(512);
    let obs = isf::load_observations(&net.dir.join("activations.bin")).unwrap();
    let tapes: Vec<_> = obs
        .iter()
        .map(|o| {
            let l = isf::extract(o, &isf::IsfConfig { max_patterns: 1500 });
            let s = synth::optimize_layer(&o.name, &l, &synth::SynthConfig::default());
            s.tape
        })
        .collect();
    // Serve at the 256-lane width: agreement must hold at any plane width.
    let logic = engine::LogicEngine::<nullanet::util::W256>::new(net.clone(), tapes).unwrap();
    let thresh = engine::ThresholdEngine::new(net).unwrap();
    let images: Vec<&[f32]> = (0..ds.n).map(|i| ds.image(i)).collect();
    let (a, b) = (logic.infer_batch(&images), thresh.infer_batch(&images));
    let agree = a
        .iter()
        .zip(&b)
        .filter(|(x, y)| model::argmax(x) == model::argmax(y))
        .count();
    // With a small ISF cap the logic net is an approximation of the
    // threshold net; most predictions must still agree.
    assert!(
        agree as f64 / ds.n as f64 > 0.7,
        "only {agree}/{} predictions agree",
        ds.n
    );
}

#[test]
fn cnn_threshold_spec_matches_f32_forward() {
    let Some(art) = artifacts() else {
        eprintln!("skipping: artifacts missing");
        return;
    };
    let net = art.net("net21").unwrap();
    // conv2 threshold layer exists and has the right shape.
    let t = net.threshold_conv2().unwrap();
    assert_eq!((t.n_in, t.n_out), (90, 20));
    // f32 forward runs and is sane on a few images.
    let ds = data::Dataset::load(&art.test_path).unwrap().take(32);
    let acc = net.accuracy_f32(&ds, true).unwrap();
    assert!(acc > 0.5, "{acc}");
}
