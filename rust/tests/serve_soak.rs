//! Soak: the event-loop server under many concurrent connections.
//!
//! What this pins down (the claims DESIGN.md makes about the serving
//! core):
//!
//! * 256+ simultaneously-open connections served by a bounded thread
//!   set (one loop thread + the per-model worker pools — not a thread
//!   per connection);
//! * a client that floods requests at an overloaded model gets a
//!   structured `{"error":…,"shed":true}` reply **delivered**, never a
//!   hang, and the stream keeps working afterwards;
//! * `{"cmd":"metrics"}` reports the overload surface: `p99_us`,
//!   `p999_us`, `shed_total`, `open_conns`;
//! * shutdown drains: every request the server accepted is answered
//!   before its connection closes (zero dropped in-flight).
//!
//! `NULLANET_BENCH_CAP=<n>` scales the connection counts down for
//! constrained CI runners; `NULLANET_POLL_BACKEND=poll` exercises the
//! portable backend (both are honored transparently by the library).

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;

use nullanet::coordinator::{engine::InferenceEngine, CoordinatorConfig};
use nullanet::jsonio::Json;
use nullanet::registry::{ModelMeta, ModelRegistry};
use nullanet::server::Server;

/// Classifies an image as the (rounded) sum of its values mod 10.
struct Echo;
impl InferenceEngine for Echo {
    fn infer_batch(&self, images: &[&[f32]]) -> Vec<Vec<f32>> {
        images
            .iter()
            .map(|img| {
                let mut l = vec![0.0; 10];
                l[img.iter().sum::<f32>() as usize % 10] = 1.0;
                l
            })
            .collect()
    }
    fn name(&self) -> &str {
        "echo"
    }
}

/// Echo, delayed: every batch takes `ms` milliseconds, so work is
/// demonstrably in flight when the test acts.
struct SlowEcho(u64);
impl InferenceEngine for SlowEcho {
    fn infer_batch(&self, images: &[&[f32]]) -> Vec<Vec<f32>> {
        std::thread::sleep(Duration::from_millis(self.0));
        Echo.infer_batch(images)
    }
    fn name(&self) -> &str {
        "slow-echo"
    }
}

/// Scale a connection count down under `NULLANET_BENCH_CAP` (small CI
/// runners), keeping at least 8 so the test still means something.
fn scaled(n: usize) -> usize {
    match std::env::var("NULLANET_BENCH_CAP").ok().and_then(|v| v.parse::<usize>().ok()) {
        Some(cap) if cap > 0 => n.min(cap.max(8)),
        _ => n,
    }
}

fn registry_of(engine: Arc<dyn InferenceEngine>, cfg: CoordinatorConfig) -> Arc<ModelRegistry> {
    let reg = Arc::new(ModelRegistry::new(cfg, 64));
    let meta = ModelMeta::for_engine("echo", engine.as_ref(), 64);
    reg.register(meta, engine).unwrap();
    reg
}

fn connect(addr: std::net::SocketAddr) -> (TcpStream, BufReader<TcpStream>) {
    let conn = TcpStream::connect(addr).unwrap();
    let reader = BufReader::new(conn.try_clone().unwrap());
    (conn, reader)
}

/// Threads in this process (Linux); None elsewhere.  Used to show the
/// server holds no per-connection threads.
fn process_threads() -> Option<usize> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    status
        .lines()
        .find(|l| l.starts_with("Threads:"))
        .and_then(|l| l.split_whitespace().nth(1))
        .and_then(|n| n.parse().ok())
}

#[test]
fn soak_256_connections_one_loop_thread() {
    let n = scaled(256);
    let reg = registry_of(Arc::new(Echo), CoordinatorConfig::default());
    let server = Server::start("127.0.0.1:0", reg).unwrap();

    // Open every connection up front and keep all of them live.
    let mut conns: Vec<(TcpStream, BufReader<TcpStream>)> =
        (0..n).map(|_| connect(server.addr)).collect();

    // A thread per connection would put this process far beyond 100
    // threads at n=256; the event loop holds it to the loop thread plus
    // the worker pool (plus whatever the test harness itself runs).
    if let Some(threads) = process_threads() {
        assert!(
            threads < 100,
            "expected a bounded thread set with {n} open connections, found {threads}"
        );
    }

    // One pipelined request per connection, all written before any
    // reply is read: the server must serve them concurrently.
    for (i, (c, _)) in conns.iter_mut().enumerate() {
        c.write_all(format!("{{\"id\": {i}, \"image\": [{}.0]}}\n", i % 10).as_bytes())
            .unwrap();
    }
    for (i, (_, r)) in conns.iter_mut().enumerate() {
        let mut line = String::new();
        r.read_line(&mut line).unwrap();
        let j = Json::parse(line.trim()).unwrap_or_else(|e| panic!("bad reply {line:?}: {e}"));
        assert_eq!(j.get("class").and_then(Json::as_usize), Some(i % 10), "{line}");
        assert_eq!(j.get("id").and_then(Json::as_usize), Some(i), "{line}");
    }

    // The metrics surface reports the overload gauges, with every
    // connection still open.
    let (c, r) = &mut conns[0];
    c.write_all(b"{\"cmd\": \"metrics\"}\n").unwrap();
    let mut line = String::new();
    r.read_line(&mut line).unwrap();
    let j = Json::parse(line.trim()).unwrap();
    assert_eq!(j.get("requests").and_then(Json::as_usize), Some(n), "{line}");
    assert!(j.get("p99_us").is_some(), "{line}");
    assert!(j.get("p999_us").is_some(), "{line}");
    assert_eq!(j.get("shed_total").and_then(Json::as_usize), Some(0), "{line}");
    assert_eq!(j.get("open_conns").and_then(Json::as_usize), Some(n), "{line}");

    // Shutdown with every connection open: prompt, and every client
    // sees a clean EOF (not a hang, not a reset mid-line).
    let t0 = std::time::Instant::now();
    server.shutdown();
    assert!(t0.elapsed() < Duration::from_secs(10), "shutdown took {:?}", t0.elapsed());
    for (_, r) in conns.iter_mut().take(8) {
        let mut line = String::new();
        assert_eq!(r.read_line(&mut line).unwrap_or(0), 0, "expected EOF, got {line:?}");
    }
}

#[test]
fn overloaded_model_sheds_with_a_delivered_reply() {
    // A one-deep queue over a slow engine: most of a request burst must
    // be shed.  The client is a deliberately slow reader — it writes
    // the whole burst before reading anything, so replies pile up
    // server-side and the loop's write backpressure is exercised too.
    let burst = scaled(64);
    let reg = registry_of(
        Arc::new(SlowEcho(25)),
        CoordinatorConfig {
            max_batch: 1,
            queue_depth: 1,
            workers: 1,
            max_wait: Duration::from_millis(1),
        },
    );
    let server = Server::start("127.0.0.1:0", Arc::clone(&reg)).unwrap();
    let (mut conn, mut reader) = connect(server.addr);
    for i in 0..burst {
        conn.write_all(format!("{{\"id\": {i}, \"image\": [1.0]}}\n").as_bytes()).unwrap();
    }
    let mut served = 0usize;
    let mut shed = 0usize;
    for _ in 0..burst {
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let j = Json::parse(line.trim()).unwrap_or_else(|e| panic!("bad reply {line:?}: {e}"));
        if j.get("shed").and_then(Json::as_bool) == Some(true) {
            let msg = j.get("error").and_then(Json::as_str).unwrap_or("");
            assert!(msg.contains("queue is full"), "{line}");
            shed += 1;
        } else {
            assert_eq!(j.get("class").and_then(Json::as_usize), Some(1), "{line}");
            served += 1;
        }
    }
    assert!(shed >= 1, "a one-deep queue never shed across a burst of {burst}");
    assert!(served >= 1, "everything was shed — nothing served");

    // The stream survives shedding: a later request on the same
    // connection is served normally.
    conn.write_all(b"{\"cmd\": \"metrics\"}\n").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let j = Json::parse(line.trim()).unwrap();
    let total = j.get("shed_total").and_then(Json::as_usize).unwrap();
    assert_eq!(total, shed, "metrics shed_total disagrees with delivered shed replies");
    drop(conn);
    server.shutdown();
}

#[test]
fn shutdown_answers_every_in_flight_request() {
    let n = scaled(32);
    // A generous batching window collects the whole burst into one
    // slow block, so the drain is one engine call, comfortably inside
    // the server's drain deadline even on slow runners.
    let reg = registry_of(
        Arc::new(SlowEcho(300)),
        CoordinatorConfig {
            workers: 2,
            max_wait: Duration::from_millis(50),
            ..Default::default()
        },
    );
    let server = Server::start("127.0.0.1:0", reg).unwrap();
    let mut conns: Vec<(TcpStream, BufReader<TcpStream>)> =
        (0..n).map(|_| connect(server.addr)).collect();
    for (i, (c, _)) in conns.iter_mut().enumerate() {
        c.write_all(format!("{{\"id\": {i}, \"image\": [2.0]}}\n").as_bytes()).unwrap();
    }
    // Give the loop time to parse and submit everything, so the whole
    // burst is genuinely in flight (the engine itself holds each batch
    // for 300 ms), then shut down while the answers are still pending.
    std::thread::sleep(Duration::from_millis(150));
    let t0 = std::time::Instant::now();
    server.shutdown();
    assert!(t0.elapsed() < Duration::from_secs(10), "drain took {:?}", t0.elapsed());
    // Zero dropped in-flight: every accepted request was answered
    // before its connection closed.
    for (i, (_, r)) in conns.iter_mut().enumerate() {
        let mut line = String::new();
        let got = r.read_line(&mut line).unwrap_or(0);
        assert!(got > 0, "request {i} dropped on shutdown");
        let j = Json::parse(line.trim()).unwrap_or_else(|e| panic!("bad reply {line:?}: {e}"));
        assert_eq!(j.get("class").and_then(Json::as_usize), Some(2), "{line}");
        line.clear();
        assert_eq!(r.read_line(&mut line).unwrap_or(0), 0, "expected EOF after the reply");
    }
}
