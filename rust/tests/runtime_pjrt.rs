//! Integration: PJRT runtime against the AOT artifacts (skips politely
//! when `make artifacts` hasn't been run).
//!
//! Compiled only with the `pjrt` feature: the default build stubs the
//! runtime out because the `xla` crate is unavailable offline.

#![cfg(feature = "pjrt")]

use nullanet::coordinator::engine::{InferenceEngine, XlaEngine};
use nullanet::{data, model, runtime};

fn artifacts() -> Option<model::Artifacts> {
    model::Artifacts::load(&nullanet::artifacts_dir()).ok()
}

#[test]
fn pjrt_client_is_available() {
    assert!(runtime::pjrt_available());
}

#[test]
fn fp32_baseline_graph_matches_python_logits() {
    // net12 (ReLU fp32) has no sign discontinuities: the PJRT-executed
    // pallas graph must match python's reference logits tightly.
    let Some(art) = artifacts() else {
        eprintln!("skipping: artifacts missing");
        return;
    };
    let net = art.net("net12").unwrap();
    let eng = XlaEngine::from_net(net, "model_b64", 64, 784, 10).unwrap();
    let ds = data::Dataset::load(&art.test_path).unwrap();
    let images: Vec<&[f32]> = (0..64).map(|i| ds.image(i)).collect();
    let out = eng.infer_batch(&images);
    let refl = model::load_reference_logits(&net.dir.join("logits.bin")).unwrap();
    for s in 0..64 {
        for j in 0..10 {
            let (a, b) = (out[s][j], refl[s][j]);
            assert!(
                (a - b).abs() < 1e-3 + 1e-3 * b.abs(),
                "sample {s} logit {j}: {a} vs {b}"
            );
        }
    }
}

#[test]
fn binary_graph_agrees_on_argmax() {
    // net11's pallas graph may flip borderline sign bits (different f32
    // reduction order), so compare top-1 agreement, not raw logits.
    let Some(art) = artifacts() else {
        eprintln!("skipping: artifacts missing");
        return;
    };
    let net = art.net("net11").unwrap();
    let eng = XlaEngine::from_net(net, "model_b64", 64, 784, 10).unwrap();
    let ds = data::Dataset::load(&art.test_path).unwrap();
    let images: Vec<&[f32]> = (0..64).map(|i| ds.image(i)).collect();
    let out = eng.infer_batch(&images);
    let refl = model::load_reference_logits(&net.dir.join("logits.bin")).unwrap();
    let mut agree = 0;
    for s in 0..64 {
        if model::argmax(&out[s]) == model::argmax(&refl[s]) {
            agree += 1;
        }
    }
    assert!(agree >= 58, "argmax agreement too low: {agree}/64");
}

#[test]
fn rust_f32_forward_matches_python_logits() {
    let Some(art) = artifacts() else {
        eprintln!("skipping: artifacts missing");
        return;
    };
    for name in ["net11", "net12"] {
        let net = art.net(name).unwrap();
        let ds = data::Dataset::load(&art.test_path).unwrap();
        let refl = model::load_reference_logits(&net.dir.join("logits.bin")).unwrap();
        let binary = name == "net11";
        for s in 0..16 {
            let l = net.forward_f32(ds.image(s), binary).unwrap();
            for j in 0..10 {
                assert!(
                    (l[j] - refl[s][j]).abs() < 1e-3 + 1e-3 * refl[s][j].abs(),
                    "{name} sample {s} logit {j}: {} vs {}",
                    l[j],
                    refl[s][j]
                );
            }
        }
    }
}

#[test]
fn first_layer_artifact_produces_bits() {
    let Some(art) = artifacts() else {
        eprintln!("skipping: artifacts missing");
        return;
    };
    let net = art.net("net11").unwrap();
    let eng = XlaEngine::from_net(net, "first_layer_b64", 64, 784, 100).unwrap();
    let ds = data::Dataset::load(&art.test_path).unwrap();
    let images: Vec<&[f32]> = (0..64).map(|i| ds.image(i)).collect();
    let out = eng.infer_batch(&images);
    assert!(out
        .iter()
        .flatten()
        .all(|&v| v == 0.0 || v == 1.0), "outputs must be bits");
    // Cross-check against the rust-computed first layer (exact function).
    let w = &net.tensors["w1"];
    let sc = &net.tensors["scale1"];
    let bi = &net.tensors["bias1"];
    let mut agree = 0usize;
    for s in 0..8 {
        let img = ds.image(s);
        for j in 0..100 {
            let mut z = 0f32;
            for i in 0..784 {
                z += img[i] * w.f32s[i * 100 + j];
            }
            let bit = (z * sc.f32s[j] + bi.f32s[j] >= 0.0) as u8 as f32;
            if bit == out[s][j] {
                agree += 1;
            }
        }
    }
    assert!(agree >= 8 * 100 - 8, "first-layer bit agreement {agree}/800");
}
