//! Serve smoke: boots the real TCP server over real `.nnc` artifacts
//! and exercises the v2 serving story end-to-end —
//!
//! * two compiled models resident in one process, served concurrently,
//! * runtime load/unload over the admin surface,
//! * hot-swap with zero failed in-flight requests,
//! * the distillation loop: an in-Rust-trained artifact retrained and
//!   swapped under hammering traffic (zero failures, new generation and
//!   provenance visible over `info`),
//! * a structurally invalid artifact refused at swap time (stable
//!   `NL021` code, zero dropped requests, live model untouched),
//! * a pipelined connection whose replies complete out of order and
//!   reassemble by `"id"`,
//! * a worker panic (injected via the deterministic fault harness)
//!   converting its in-flight requests to structured error replies,
//!   with the supervisor restarting the worker and the very next
//!   request succeeding.
//!
//! The artifacts are built in-process (tiny 2-2-2-2 MLPs whose one
//! hidden tape either passes bits through or swaps them, so the two
//! models give different classes for the same image) and go through the
//! full `CompiledModel::save` → `load_artifact` → `engine_from_artifact`
//! path — no `make artifacts` needed, which is what lets CI run this as
//! its serve-smoke job.

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Duration;

use nullanet::aig::Aig;
use nullanet::artifact::{CompiledLayer, CompiledModel, LayerStats};
use nullanet::coordinator::{engine::InferenceEngine, CoordinatorConfig};
use nullanet::jsonio::Json;
use nullanet::model::{Arch, Tensor};
use nullanet::netlist::LogicTape;
use nullanet::registry::{ModelMeta, ModelRegistry};
use nullanet::server::Server;

/// Build and save a tiny compiled model.  First layer thresholds each
/// input at 0.5; the hidden tape is identity or bit-swap; the last
/// layer maps bit j to logit j.  Image (0.9, 0.1) ⇒ class 0 (identity)
/// or class 1 (swap).
fn tiny_artifact(dir: &Path, name: &str, swap: bool) -> PathBuf {
    let mut g = Aig::new(2);
    let (a, b) = (g.pi(0), g.pi(1));
    if swap {
        g.add_output(b);
        g.add_output(a);
    } else {
        g.add_output(a);
        g.add_output(b);
    }
    let tape = LogicTape::from_aig(&g);
    let t = |shape: Vec<usize>, f32s: Vec<f32>| Tensor { shape, f32s };
    let mut params = BTreeMap::new();
    params.insert("w1".to_string(), t(vec![2, 2], vec![1.0, 0.0, 0.0, 1.0]));
    params.insert("scale1".to_string(), t(vec![2], vec![1.0, 1.0]));
    params.insert("bias1".to_string(), t(vec![2], vec![-0.5, -0.5]));
    params.insert("w3".to_string(), t(vec![2, 2], vec![1.0, 0.0, 0.0, 1.0]));
    params.insert("scale3".to_string(), t(vec![2], vec![1.0, 1.0]));
    params.insert("bias3".to_string(), t(vec![2], vec![0.0, 0.0]));
    let cm = CompiledModel {
        name: name.to_string(),
        arch: Arch::Mlp { sizes: vec![2, 2, 2, 2] },
        accuracy_test: f64::NAN,
        layers: vec![CompiledLayer {
            name: "layer2".to_string(),
            tape,
            stats: LayerStats::default(),
        }],
        params,
        provenance: None,
    };
    std::fs::create_dir_all(dir).unwrap();
    let path = dir.join(format!("{name}.nnc"));
    cm.save(&path).unwrap();
    path
}

fn tmp(test: &str) -> PathBuf {
    std::env::temp_dir().join(format!("nullanet_serve_smoke_{test}"))
}

fn registry(workers: usize) -> Arc<ModelRegistry> {
    Arc::new(ModelRegistry::new(
        CoordinatorConfig { workers, max_wait: Duration::from_millis(1), ..Default::default() },
        64,
    ))
}

fn connect(addr: std::net::SocketAddr) -> (TcpStream, BufReader<TcpStream>) {
    let conn = TcpStream::connect(addr).unwrap();
    let reader = BufReader::new(conn.try_clone().unwrap());
    (conn, reader)
}

fn request(conn: &mut TcpStream, reader: &mut BufReader<TcpStream>, line: &str) -> Json {
    conn.write_all(line.as_bytes()).unwrap();
    conn.write_all(b"\n").unwrap();
    let mut reply = String::new();
    reader.read_line(&mut reply).unwrap();
    Json::parse(reply.trim()).unwrap_or_else(|e| panic!("bad reply {reply:?}: {e}"))
}

fn class_of(j: &Json) -> usize {
    j.get("class")
        .and_then(Json::as_usize)
        .unwrap_or_else(|| panic!("no class in {j:?}"))
}

#[test]
fn two_artifact_models_served_concurrently() {
    let dir = tmp("two_models");
    let ident = tiny_artifact(&dir, "ident", false);
    let swap = tiny_artifact(&dir, "swapm", true);
    let reg = registry(2);
    reg.load_artifact(None, ident.to_str().unwrap(), None).unwrap();
    reg.load_artifact(None, swap.to_str().unwrap(), None).unwrap();
    let server = Server::start("127.0.0.1:0", Arc::clone(&reg)).unwrap();

    // Same image, both models, one connection: different answers.
    let (mut conn, mut reader) = connect(server.addr);
    let a = request(&mut conn, &mut reader, "{\"model\": \"ident\", \"image\": [0.9, 0.1]}");
    let b = request(&mut conn, &mut reader, "{\"model\": \"swapm\", \"image\": [0.9, 0.1]}");
    assert_eq!(class_of(&a), 0);
    assert_eq!(class_of(&b), 1);
    // Client-side batching routes through the same model.
    let batch = request(
        &mut conn,
        &mut reader,
        "{\"id\": 1, \"model\": \"swapm\", \"images\": [[0.9, 0.1], [0.1, 0.9]]}",
    );
    let classes: Vec<usize> = batch
        .get("results")
        .and_then(Json::as_arr)
        .unwrap()
        .iter()
        .map(class_of)
        .collect();
    assert_eq!(classes, vec![1, 0]);
    drop(conn);

    // Concurrent clients pinned to different models.
    let mut handles = vec![];
    for (model, want) in [("ident", 0usize), ("swapm", 1usize)] {
        let addr = server.addr;
        handles.push(std::thread::spawn(move || {
            let (mut conn, mut reader) = connect(addr);
            for _ in 0..50 {
                let j = request(
                    &mut conn,
                    &mut reader,
                    &format!("{{\"model\": \"{model}\", \"image\": [0.9, 0.1]}}"),
                );
                assert_eq!(class_of(&j), want, "{model}");
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    server.shutdown();
}

#[test]
fn admin_load_list_unload_over_the_socket() {
    let dir = tmp("admin");
    let ident = tiny_artifact(&dir, "ident", false);
    let swap = tiny_artifact(&dir, "swapm", true);
    let reg = registry(1);
    reg.load_artifact(None, ident.to_str().unwrap(), None).unwrap();
    let server = Server::start("127.0.0.1:0", Arc::clone(&reg)).unwrap();
    let (mut conn, mut reader) = connect(server.addr);

    let j = request(&mut conn, &mut reader, "{\"cmd\": \"list\"}");
    assert_eq!(j.get("models").and_then(Json::as_arr).unwrap().len(), 1);
    assert_eq!(j.get("default").and_then(Json::as_str), Some("ident"));

    let j = request(
        &mut conn,
        &mut reader,
        &format!("{{\"cmd\": \"load\", \"artifact\": {:?}}}", swap.to_str().unwrap()),
    );
    assert_eq!(j.get("loaded").and_then(Json::as_str), Some("swapm"));
    let j = request(&mut conn, &mut reader, "{\"cmd\": \"list\"}");
    assert_eq!(j.get("models").and_then(Json::as_arr).unwrap().len(), 2);

    // Loading the same name again must be rejected (swap is the tool).
    let j = request(
        &mut conn,
        &mut reader,
        &format!("{{\"cmd\": \"load\", \"artifact\": {:?}}}", swap.to_str().unwrap()),
    );
    assert!(
        j.get("error").and_then(Json::as_str).unwrap_or("").contains("already loaded"),
        "{j:?}"
    );

    let j = request(&mut conn, &mut reader, "{\"model\": \"swapm\", \"image\": [0.9, 0.1]}");
    assert_eq!(class_of(&j), 1);

    let j = request(&mut conn, &mut reader, "{\"cmd\": \"unload\", \"name\": \"swapm\"}");
    assert_eq!(j.get("unloaded").and_then(Json::as_str), Some("swapm"));
    let j = request(&mut conn, &mut reader, "{\"model\": \"swapm\", \"image\": [0.9, 0.1]}");
    assert!(
        j.get("error").and_then(Json::as_str).unwrap_or("").contains("unknown model"),
        "{j:?}"
    );

    drop(conn);
    server.shutdown();
}

#[test]
fn hot_swap_has_zero_failed_in_flight_requests() {
    let dir = tmp("hot_swap");
    let ident = tiny_artifact(&dir, "ident", false);
    let swap = tiny_artifact(&dir, "swapm", true);
    let reg = registry(2);
    // Both incarnations serve under the registry name "hot".
    reg.load_artifact(Some("hot"), ident.to_str().unwrap(), None).unwrap();
    let server = Server::start("127.0.0.1:0", Arc::clone(&reg)).unwrap();

    // Hammer threads: v1-style requests against the default model while
    // the swap happens.  Every reply must be a class (0 before the swap,
    // 1 after) — never an error line.
    let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
    let mut handles = vec![];
    for _ in 0..4 {
        let addr = server.addr;
        let stop = Arc::clone(&stop);
        handles.push(std::thread::spawn(move || {
            let (mut conn, mut reader) = connect(addr);
            let mut served = 0usize;
            while !stop.load(std::sync::atomic::Ordering::SeqCst) {
                let j = request(&mut conn, &mut reader, "{\"image\": [0.9, 0.1]}");
                assert!(
                    j.get("error").is_none(),
                    "in-flight request failed during hot-swap: {j:?}"
                );
                let c = class_of(&j);
                assert!(c == 0 || c == 1, "nonsense class {c}");
                served += 1;
            }
            served
        }));
    }

    // Let traffic build, then swap over the admin surface.
    std::thread::sleep(Duration::from_millis(100));
    let (mut admin, mut admin_reader) = connect(server.addr);
    let j = request(
        &mut admin,
        &mut admin_reader,
        &format!(
            "{{\"cmd\": \"swap\", \"name\": \"hot\", \"artifact\": {:?}}}",
            swap.to_str().unwrap()
        ),
    );
    assert_eq!(j.get("swapped").and_then(Json::as_str), Some("hot"), "{j:?}");
    assert!(j.get("generation").and_then(Json::as_usize).unwrap() >= 2);

    // Traffic keeps flowing across the swap boundary.
    std::thread::sleep(Duration::from_millis(100));
    stop.store(true, std::sync::atomic::Ordering::SeqCst);
    let served: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
    assert!(served > 20, "hammer barely ran ({served} requests)");

    // Post-swap, the new incarnation answers.
    let j = request(&mut admin, &mut admin_reader, "{\"image\": [0.9, 0.1]}");
    assert_eq!(class_of(&j), 1, "swap did not take effect: {j:?}");
    let j = request(&mut admin, &mut admin_reader, "{\"cmd\": \"info\"}");
    assert_eq!(j.get("model").and_then(Json::as_str), Some("hot"));
    drop(admin);
    server.shutdown();
}

/// Train a tiny net with the in-Rust trainer and save it as a `.nnc` —
/// the exact pipeline behind `nullanet train`, shrunk to smoke size.
fn trained_artifact(dir: &Path, name: &str, ds: &nullanet::data::Dataset, seed: u64) -> PathBuf {
    use nullanet::train::{self, TrainConfig};
    let cfg = TrainConfig {
        epochs: 2,
        batch: 16,
        seed,
        val_frac: 0.125,
        ..TrainConfig::new(vec![8, 6, 6, 2])
    };
    let trained = train::train(ds, &cfg).unwrap();
    let scfg = nullanet::synth::SynthConfig { threads: 1, ..Default::default() };
    let (cm, _) = train::compile_trained(name, &trained, &cfg, ds, 1000, &scfg).unwrap();
    std::fs::create_dir_all(dir).unwrap();
    let path = dir.join(format!("{name}-{seed}.nnc"));
    cm.save(&path).unwrap();
    path
}

#[test]
fn distill_retrain_then_swap_under_traffic_drops_nothing() {
    use nullanet::train;

    let dir = tmp("distill");
    let ds = train::synthetic_digits(96, 8, 2, 3);
    let v1 = trained_artifact(&dir, "distilled", &ds, 5);
    let reg = registry(2);
    reg.load_artifact(None, v1.to_str().unwrap(), None).unwrap();
    let server = Server::start("127.0.0.1:0", Arc::clone(&reg)).unwrap();

    // Hammer threads against the trained model while the retrained
    // incarnation swaps in: every reply must be a class, never an error.
    let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
    let mut handles = vec![];
    for _ in 0..4 {
        let addr = server.addr;
        let stop = Arc::clone(&stop);
        handles.push(std::thread::spawn(move || {
            let (mut conn, mut reader) = connect(addr);
            let mut served = 0usize;
            while !stop.load(std::sync::atomic::Ordering::SeqCst) {
                let j = request(
                    &mut conn,
                    &mut reader,
                    "{\"image\": [0.9, 0.1, 0.8, 0.2, 0.7, 0.3, 0.6, 0.4]}",
                );
                assert!(
                    j.get("error").is_none(),
                    "in-flight request failed during distill swap: {j:?}"
                );
                assert!(class_of(&j) < 2, "nonsense class in {j:?}");
                served += 1;
            }
            served
        }));
    }

    // Retrain with a different seed while traffic runs (the distill
    // path: new training run → new artifact → swap over the admin
    // socket), then swap it in.
    std::thread::sleep(Duration::from_millis(50));
    let v2 = trained_artifact(&dir, "distilled", &ds, 6);
    let (mut admin, mut admin_reader) = connect(server.addr);
    let j = request(
        &mut admin,
        &mut admin_reader,
        &format!(
            "{{\"cmd\": \"swap\", \"name\": \"distilled\", \"artifact\": {:?}}}",
            v2.to_str().unwrap()
        ),
    );
    assert_eq!(j.get("swapped").and_then(Json::as_str), Some("distilled"), "{j:?}");
    let generation = j.get("generation").and_then(Json::as_usize).unwrap();
    assert!(generation >= 2, "swap did not bump the generation: {j:?}");

    // Traffic keeps flowing across the swap boundary.
    std::thread::sleep(Duration::from_millis(100));
    stop.store(true, std::sync::atomic::Ordering::SeqCst);
    let served: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
    assert!(served > 20, "hammer barely ran ({served} requests)");

    // `info` reports the new generation and the retrained provenance.
    let j = request(&mut admin, &mut admin_reader, "{\"cmd\": \"info\", \"model\": \"distilled\"}");
    assert_eq!(j.get("generation").and_then(Json::as_usize), Some(generation), "{j:?}");
    let prov = j.get("provenance").unwrap_or_else(|| panic!("no provenance in {j:?}"));
    assert_eq!(prov.get("seed").and_then(Json::as_str), Some("6"), "{j:?}");
    assert_eq!(prov.get("rule").and_then(Json::as_str), Some("ste"), "{j:?}");
    assert_eq!(
        prov.get("dataset_digest").and_then(Json::as_str),
        Some(format!("{:016x}", nullanet::artifact::dataset_digest(&ds)).as_str()),
        "{j:?}"
    );

    drop(admin);
    server.shutdown();
}

#[test]
fn invalid_artifact_swap_is_rejected_under_load_with_zero_failures() {
    let dir = tmp("bad_swap");
    let ident = tiny_artifact(&dir, "ident", false);
    let swap = tiny_artifact(&dir, "swapm", true);
    // Corrupt the replacement: rename its layer section, so every line
    // still parses but the section digest cannot match (NL021) — the
    // structurally-subtle kind of damage only the verifier catches.
    let corrupt = dir.join("corrupt.nnc");
    let text = std::fs::read_to_string(&swap).unwrap();
    let bad = text.replacen("\"name\":\"layer2\"", "\"name\":\"layerX\"", 1);
    assert_ne!(bad, text, "corruption was a no-op");
    std::fs::write(&corrupt, bad).unwrap();

    let reg = registry(2);
    reg.load_artifact(Some("hot"), ident.to_str().unwrap(), None).unwrap();
    let server = Server::start("127.0.0.1:0", Arc::clone(&reg)).unwrap();

    // Hammer threads: the rejected swap must never surface to serving
    // traffic — every reply stays class 0 (the resident incarnation),
    // never an error, with requests in flight across the attempt.
    let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
    let mut handles = vec![];
    for _ in 0..4 {
        let addr = server.addr;
        let stop = Arc::clone(&stop);
        handles.push(std::thread::spawn(move || {
            let (mut conn, mut reader) = connect(addr);
            let mut served = 0usize;
            while !stop.load(std::sync::atomic::Ordering::SeqCst) {
                let j = request(&mut conn, &mut reader, "{\"image\": [0.9, 0.1]}");
                assert!(
                    j.get("error").is_none(),
                    "in-flight request failed during rejected swap: {j:?}"
                );
                assert_eq!(class_of(&j), 0, "rejected artifact leaked into serving");
                served += 1;
            }
            served
        }));
    }

    std::thread::sleep(Duration::from_millis(100));
    let (mut admin, mut admin_reader) = connect(server.addr);

    // The admin verify command sees the damage without touching the
    // registry, and names it with the stable code.
    let j = request(
        &mut admin,
        &mut admin_reader,
        &format!("{{\"cmd\": \"verify\", \"artifact\": {:?}}}", corrupt.to_str().unwrap()),
    );
    assert_eq!(j.get("ok").and_then(Json::as_bool), Some(false), "{j:?}");
    let diag_codes: Vec<&str> = j
        .get("diags")
        .and_then(Json::as_arr)
        .unwrap()
        .iter()
        .filter_map(|d| d.get("code").and_then(Json::as_str))
        .collect();
    assert!(diag_codes.contains(&"NL021"), "{j:?}");

    // The swap itself is refused, with the code in the error reply.
    let j = request(
        &mut admin,
        &mut admin_reader,
        &format!(
            "{{\"cmd\": \"swap\", \"name\": \"hot\", \"artifact\": {:?}}}",
            corrupt.to_str().unwrap()
        ),
    );
    let err = j.get("error").and_then(Json::as_str).unwrap_or("");
    assert!(err.contains("NL021"), "swap of corrupt artifact not refused: {j:?}");

    // Traffic keeps flowing; nothing was dropped or reclassified.
    std::thread::sleep(Duration::from_millis(100));
    stop.store(true, std::sync::atomic::Ordering::SeqCst);
    let served: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
    assert!(served > 20, "hammer barely ran ({served} requests)");

    // The live incarnation is untouched...
    let j = request(&mut admin, &mut admin_reader, "{\"image\": [0.9, 0.1]}");
    assert_eq!(class_of(&j), 0, "live model displaced by a rejected artifact: {j:?}");
    // ...and the registry is not wedged: a good swap still goes through.
    let j = request(
        &mut admin,
        &mut admin_reader,
        &format!(
            "{{\"cmd\": \"swap\", \"name\": \"hot\", \"artifact\": {:?}}}",
            swap.to_str().unwrap()
        ),
    );
    assert_eq!(j.get("swapped").and_then(Json::as_str), Some("hot"), "{j:?}");
    let j = request(&mut admin, &mut admin_reader, "{\"image\": [0.9, 0.1]}");
    assert_eq!(class_of(&j), 1, "good swap after rejection did not take: {j:?}");

    drop(admin);
    server.shutdown();
}

#[test]
fn pipelined_replies_complete_out_of_order_and_reassemble_by_id() {
    /// Sleeps image[0] milliseconds, classifies as image[1].
    struct SleepEngine;
    impl InferenceEngine for SleepEngine {
        fn infer_batch(&self, images: &[&[f32]]) -> Vec<Vec<f32>> {
            images
                .iter()
                .map(|img| {
                    std::thread::sleep(Duration::from_millis(img[0] as u64));
                    let mut l = vec![0.0; 10];
                    l[img[1] as usize % 10] = 1.0;
                    l
                })
                .collect()
        }
        fn name(&self) -> &str {
            "sleep"
        }
        fn preferred_block(&self) -> usize {
            1 // every request its own block, so blocks overlap in time
        }
    }

    let reg = registry(3);
    let eng = Arc::new(SleepEngine);
    reg.register(ModelMeta::for_engine("sleep", eng.as_ref(), 64), eng).unwrap();
    let server = Server::start("127.0.0.1:0", Arc::clone(&reg)).unwrap();
    let (mut conn, mut reader) = connect(server.addr);

    // Three pipelined requests on one connection, no waiting between
    // them: the first sleeps 400 ms, the other two are instant.
    conn.write_all(
        b"{\"id\": \"slow\", \"image\": [400.0, 1.0]}\n\
          {\"id\": \"fast1\", \"image\": [0.0, 2.0]}\n\
          {\"id\": \"fast2\", \"image\": [0.0, 3.0]}\n",
    )
    .unwrap();

    let mut order = Vec::new();
    let mut by_id = BTreeMap::new();
    for _ in 0..3 {
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let j = Json::parse(line.trim()).unwrap();
        let id = j.get("id").and_then(Json::as_str).unwrap().to_string();
        order.push(id.clone());
        by_id.insert(id, class_of(&j));
    }
    // Reassembly: every id answered with its own class.
    assert_eq!(by_id.get("slow"), Some(&1));
    assert_eq!(by_id.get("fast1"), Some(&2));
    assert_eq!(by_id.get("fast2"), Some(&3));
    // Out-of-order completion: the slow request must not come first.
    assert_ne!(order[0], "slow", "replies arrived in submission order: {order:?}");
    assert_eq!(order[2], "slow", "slow reply should complete last: {order:?}");

    drop(conn);
    server.shutdown();
}

#[test]
fn worker_panic_gets_error_replies_and_the_pool_recovers() {
    /// Classifies as image[0]; the fault harness injects the panics.
    struct ChaosEngine;
    impl InferenceEngine for ChaosEngine {
        fn infer_batch(&self, images: &[&[f32]]) -> Vec<Vec<f32>> {
            images
                .iter()
                .map(|img| {
                    let mut l = vec![0.0; 10];
                    l[img[0] as usize % 10] = 1.0;
                    l
                })
                .collect()
        }
        fn name(&self) -> &str {
            "chaos-eng"
        }
    }

    // Deterministic injected panics, scoped to this engine's name so
    // the (process-global) plan cannot perturb the other smoke tests
    // running concurrently in this binary.
    nullanet::fault::install(7, "worker_panic@chaos-eng=1").unwrap();
    let reg = registry(2);
    let eng = Arc::new(ChaosEngine);
    reg.register(ModelMeta::for_engine("chaosm", eng.as_ref(), 64), eng).unwrap();
    let server = Server::start("127.0.0.1:0", Arc::clone(&reg)).unwrap();
    let (mut conn, mut reader) = connect(server.addr);

    // Two pipelined in-flight requests: both get structured worker-panic
    // sheds — never a hang, never a dropped connection.
    conn.write_all(
        b"{\"id\": 1, \"model\": \"chaosm\", \"image\": [4.0]}\n\
          {\"id\": 2, \"model\": \"chaosm\", \"image\": [5.0]}\n",
    )
    .unwrap();
    for _ in 0..2 {
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let j = Json::parse(line.trim()).unwrap();
        assert_eq!(j.get("error").and_then(Json::as_str), Some("worker panic"), "{j:?}");
        assert_eq!(j.get("shed").and_then(Json::as_bool), Some(true), "{j:?}");
    }
    // The supervisor restarted the worker loop and counted it, both
    // per model and in the top-level aggregate.
    let j = request(&mut conn, &mut reader, "{\"cmd\": \"metrics\"}");
    assert!(j.get("worker_restarts").and_then(Json::as_usize).unwrap() >= 1, "{j:?}");
    assert!(
        j.at(&["models", "chaosm", "worker_restarts"]).and_then(Json::as_usize).unwrap() >= 1,
        "{j:?}"
    );
    // Clear the plan: the exact same request now succeeds on the
    // restarted pool.
    nullanet::fault::install(7, "").unwrap();
    let j = request(&mut conn, &mut reader, "{\"model\": \"chaosm\", \"image\": [4.0]}");
    assert_eq!(class_of(&j), 4, "pool did not recover after injected panics: {j:?}");

    drop(conn);
    server.shutdown();
}
