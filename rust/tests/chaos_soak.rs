//! Chaos soak: the fault-tolerance acceptance test.  A seeded fault
//! plan (worker panics, inference delays, artifact write failures)
//! runs against the real server while client threads hammer it, and
//! the test asserts the blast radius stays contained:
//!
//! * **artifacts** — injected ENOSPC-style write failures never
//!   corrupt a destination `.nnc` (tmp + rename), leave sweepable
//!   `.nnc.tmp` debris, and a later clean save lands and loads;
//! * **soak** — under random worker panics and delays with a 25 ms
//!   request deadline, every request gets exactly one structured reply
//!   (class, shed, or timeout — never a hang, never a dropped
//!   connection), and the server still answers `ping` afterwards;
//! * **breaker** — a persistently panicking model trips its circuit
//!   breaker open (observable over `info`/`metrics`), and once the
//!   engine heals, cooldown → half-open probes → closed is observable
//!   step by step.
//!
//! The plan comes from `NULLANET_FAULT` when set (CI pins
//! `42:worker_panic=0.03,infer_delay=0.02:80,artifact_write@flaky=0.7`
//! and runs the test under both poll backends); unset, the same spec
//! is installed programmatically, so a bare `cargo test` exercises the
//! identical schedule.

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use nullanet::aig::Aig;
use nullanet::artifact::{self, CompiledLayer, CompiledModel, LayerStats};
use nullanet::coordinator::{engine::InferenceEngine, CoordinatorConfig};
use nullanet::jsonio::Json;
use nullanet::model::{Arch, Tensor};
use nullanet::netlist::LogicTape;
use nullanet::registry::{ModelMeta, ModelRegistry};
use nullanet::server::Server;

const DEFAULT_PLAN: &str = "42:worker_panic=0.03,infer_delay=0.02:80,artifact_write@flaky=0.7";

/// Build (but do not save) the serve-smoke tiny model: threshold at
/// 0.5, identity or bit-swap hidden tape, logit j = bit j.  Image
/// (0.9, 0.1) ⇒ class 0 (identity) or class 1 (swap).
fn tiny_model(name: &str, swap: bool) -> CompiledModel {
    let mut g = Aig::new(2);
    let (a, b) = (g.pi(0), g.pi(1));
    if swap {
        g.add_output(b);
        g.add_output(a);
    } else {
        g.add_output(a);
        g.add_output(b);
    }
    let tape = LogicTape::from_aig(&g);
    let t = |shape: Vec<usize>, f32s: Vec<f32>| Tensor { shape, f32s };
    let mut params = BTreeMap::new();
    params.insert("w1".to_string(), t(vec![2, 2], vec![1.0, 0.0, 0.0, 1.0]));
    params.insert("scale1".to_string(), t(vec![2], vec![1.0, 1.0]));
    params.insert("bias1".to_string(), t(vec![2], vec![-0.5, -0.5]));
    params.insert("w3".to_string(), t(vec![2, 2], vec![1.0, 0.0, 0.0, 1.0]));
    params.insert("scale3".to_string(), t(vec![2], vec![1.0, 1.0]));
    params.insert("bias3".to_string(), t(vec![2], vec![0.0, 0.0]));
    CompiledModel {
        name: name.to_string(),
        arch: Arch::Mlp { sizes: vec![2, 2, 2, 2] },
        accuracy_test: f64::NAN,
        layers: vec![CompiledLayer {
            name: "layer2".to_string(),
            tape,
            stats: LayerStats::default(),
        }],
        params,
        provenance: None,
    }
}

/// Save a tiny model, retrying through any injected write faults (the
/// default plan only targets the model named `flaky`, but a custom
/// `NULLANET_FAULT` may aim wider).
fn tiny_artifact(dir: &Path, name: &str, swap: bool) -> PathBuf {
    let cm = tiny_model(name, swap);
    std::fs::create_dir_all(dir).unwrap();
    let path = dir.join(format!("{name}.nnc"));
    let mut last = None;
    for _ in 0..60 {
        match cm.save(&path) {
            Ok(()) => return path,
            Err(e) => last = Some(e),
        }
    }
    panic!("could not save {name} in 60 attempts: {last:?}");
}

fn connect(addr: std::net::SocketAddr) -> (TcpStream, BufReader<TcpStream>) {
    let conn = TcpStream::connect(addr).unwrap();
    conn.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let reader = BufReader::new(conn.try_clone().unwrap());
    (conn, reader)
}

/// One request, one reply.  The 10 s read timeout on the socket is the
/// hang detector: a request the server never answers fails the test
/// here instead of wedging it.
fn request(conn: &mut TcpStream, reader: &mut BufReader<TcpStream>, line: &str) -> Json {
    conn.write_all(line.as_bytes()).unwrap();
    conn.write_all(b"\n").unwrap();
    let mut reply = String::new();
    reader.read_line(&mut reply).unwrap();
    assert!(!reply.is_empty(), "connection closed instead of replying to {line:?}");
    Json::parse(reply.trim()).unwrap_or_else(|e| panic!("bad reply {reply:?}: {e}"))
}

fn breaker_state(j: &Json) -> &str {
    j.get("breaker_state").and_then(Json::as_str).unwrap_or_else(|| panic!("no breaker in {j:?}"))
}

/// A healing engine: panics while `broken`, classifies as
/// `image[0] % 10` once fixed.  Drives the breaker state machine.
struct FlakyEngine {
    broken: AtomicBool,
}

impl InferenceEngine for FlakyEngine {
    fn infer_batch(&self, images: &[&[f32]]) -> Vec<Vec<f32>> {
        assert!(!self.broken.load(Ordering::SeqCst), "flaky engine is broken");
        images
            .iter()
            .map(|img| {
                let mut l = vec![0.0; 10];
                l[img[0] as usize % 10] = 1.0;
                l
            })
            .collect()
    }
    fn name(&self) -> &str {
        "flaky-eng"
    }
}

#[test]
fn chaos_soak() {
    let plan = std::env::var("NULLANET_FAULT").unwrap_or_else(|_| DEFAULT_PLAN.to_string());
    nullanet::fault::install_str(&plan).unwrap();
    let dir = std::env::temp_dir().join("nullanet_chaos_soak");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();

    // ---- Phase 1: crash-safe artifact writes under injected ENOSPC.
    let flaky = tiny_model("flaky", false);
    let dest = dir.join("flaky.nnc");
    if plan.contains("artifact_write@flaky") {
        let mut failed = false;
        for _ in 0..60 {
            if flaky.save(&dest).is_err() {
                failed = true;
                break;
            }
        }
        assert!(failed, "no injected write failure in 60 saves under {plan:?}");
        // The failure aborted mid-write: the destination may or may not
        // exist (earlier saves can have landed), but the orphaned tmp
        // does, and the startup-style sweep clears exactly that debris.
        let tmp = dir.join("flaky.nnc.tmp");
        assert!(tmp.exists(), "failed save left no {}", tmp.display());
        assert!(artifact::sweep_stale_tmp(&dir) >= 1);
        assert!(!tmp.exists(), "sweep left {}", tmp.display());
    }
    let mut saved = false;
    for _ in 0..60 {
        if flaky.save(&dest).is_ok() {
            saved = true;
            break;
        }
    }
    assert!(saved, "no clean save in 60 attempts under {plan:?}");
    let loaded = CompiledModel::load(&dest).unwrap();
    assert_eq!(loaded.name, "flaky", "artifact corrupted by injected faults");

    // ---- Phase 2: hammer two live models through panics + delays.
    let ident = tiny_artifact(&dir, "ident", false);
    let swap = tiny_artifact(&dir, "swapm", true);
    let reg = Arc::new(ModelRegistry::new(
        CoordinatorConfig { workers: 2, max_wait: Duration::from_millis(1), ..Default::default() },
        64,
    ));
    reg.load_artifact(None, ident.to_str().unwrap(), None).unwrap();
    reg.load_artifact(None, swap.to_str().unwrap(), None).unwrap();
    let server = Server::start_with_timeout(
        "127.0.0.1:0",
        Arc::clone(&reg),
        64,
        Some(Duration::from_millis(25)),
    )
    .unwrap();

    let mut handles = vec![];
    for t in 0..4usize {
        let addr = server.addr;
        handles.push(std::thread::spawn(move || {
            let (mut conn, mut reader) = connect(addr);
            let (mut classes, mut errors) = (0u32, 0u32);
            for i in 0..300usize {
                let model = if (t + i) % 2 == 0 { "ident" } else { "swapm" };
                let j = request(
                    &mut conn,
                    &mut reader,
                    &format!("{{\"model\": \"{model}\", \"image\": [0.9, 0.1]}}"),
                );
                match j.get("class").and_then(Json::as_usize) {
                    // A correct answer: faults shed requests, they must
                    // never corrupt one.
                    Some(c) => {
                        assert_eq!(c, if model == "ident" { 0 } else { 1 }, "{j:?}");
                        classes += 1;
                    }
                    None => {
                        assert!(j.get("error").is_some(), "reply neither class nor error: {j:?}");
                        errors += 1;
                    }
                }
            }
            (classes, errors)
        }));
    }
    let (mut classes, mut errors) = (0u32, 0u32);
    for h in handles {
        let (c, e) = h.join().expect("soak thread panicked");
        classes += c;
        errors += e;
    }
    assert_eq!(classes + errors, 1200);
    assert!(classes > 0, "every soak request failed");
    eprintln!("soak: {classes} answered, {errors} shed/timed out under {plan:?}");

    // The server survived: still answering, and the supervisor counted
    // the injected panics as worker restarts.
    let (mut conn, mut reader) = connect(server.addr);
    let j = request(&mut conn, &mut reader, "{\"cmd\": \"ping\"}");
    assert_eq!(j.get("ok").and_then(Json::as_bool), Some(true), "{j:?}");
    let m = request(&mut conn, &mut reader, "{\"cmd\": \"metrics\"}");
    if plan.contains("worker_panic=") {
        assert!(m.get("worker_restarts").and_then(Json::as_usize).unwrap() >= 1, "{m:?}");
    }
    if plan.contains("infer_delay") {
        assert!(m.get("timeout_total").and_then(Json::as_usize).unwrap() >= 1, "{m:?}");
    }
    drop(conn);
    server.shutdown();

    // ---- Phase 3: breaker trip and recovery, deterministically.  The
    // fault plan goes quiet (empty spec) so only the engine's own
    // behavior drives the state machine, on a server with no request
    // deadline so worker-restart backoff cannot race the sweep.
    nullanet::fault::install(42, "").unwrap();
    let reg = Arc::new(ModelRegistry::new(
        CoordinatorConfig { workers: 2, max_wait: Duration::from_millis(1), ..Default::default() },
        64,
    ));
    let eng = Arc::new(FlakyEngine { broken: AtomicBool::new(true) });
    let dyn_eng: Arc<dyn InferenceEngine> = Arc::clone(&eng);
    reg.register(ModelMeta::for_engine("flakym", eng.as_ref(), 64), dyn_eng).unwrap();
    let server = Server::start("127.0.0.1:0", Arc::clone(&reg)).unwrap();
    let (mut conn, mut reader) = connect(server.addr);

    // Sequential failures: worker panics feed the breaker until it
    // trips, after which requests fast-shed with a quarantine reply.
    let mut quarantined = 0;
    for _ in 0..(nullanet::registry::BREAKER_MIN_OBS + 8) {
        let j = request(&mut conn, &mut reader, "{\"model\": \"flakym\", \"image\": [4.0]}");
        let msg = j.get("error").and_then(Json::as_str).unwrap_or_else(|| panic!("{j:?}"));
        assert_eq!(j.get("shed").and_then(Json::as_bool), Some(true), "{j:?}");
        if msg.contains("quarantined") {
            quarantined += 1;
        } else {
            assert_eq!(msg, "worker panic", "{j:?}");
        }
    }
    assert!(quarantined >= 1, "breaker never tripped after repeated panics");
    let j = request(&mut conn, &mut reader, "{\"cmd\": \"info\", \"model\": \"flakym\"}");
    assert_eq!(breaker_state(&j), "open", "{j:?}");
    assert_eq!(j.get("quarantined").and_then(Json::as_bool), Some(true), "{j:?}");

    // Heal the engine, wait out the cooldown, and walk the recovery:
    // probe successes half-open the breaker, then close it.
    eng.broken.store(false, Ordering::SeqCst);
    std::thread::sleep(Duration::from_millis(nullanet::registry::BREAKER_COOLDOWN_MS + 60));
    for probe in 1..=nullanet::registry::BREAKER_CLOSE_AFTER {
        let j = request(&mut conn, &mut reader, "{\"model\": \"flakym\", \"image\": [4.0]}");
        assert_eq!(j.get("class").and_then(Json::as_usize), Some(4), "probe {probe}: {j:?}");
        if probe < nullanet::registry::BREAKER_CLOSE_AFTER {
            let j = request(&mut conn, &mut reader, "{\"cmd\": \"info\", \"model\": \"flakym\"}");
            assert_eq!(breaker_state(&j), "half-open", "probe {probe}: {j:?}");
        }
    }
    let m = request(&mut conn, &mut reader, "{\"cmd\": \"metrics\"}");
    assert_eq!(
        m.at(&["models", "flakym", "breaker_state"]).and_then(Json::as_str),
        Some("closed"),
        "{m:?}"
    );
    assert_eq!(
        m.at(&["models", "flakym", "quarantined"]).and_then(Json::as_bool),
        Some(false),
        "{m:?}"
    );
    assert!(m.at(&["models", "flakym", "worker_restarts"]).and_then(Json::as_usize).unwrap() >= 1);

    drop(conn);
    server.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}
