//! Compiled-artifact integration tests: save→load→eval must be
//! bit-identical to the in-memory tape at every supported plane width,
//! damaged/stale files must be rejected with a clear error, and engines
//! built from a loaded artifact must serve exactly the predictions the
//! synthesizing path would have.

use std::collections::BTreeMap;
use std::path::PathBuf;

use nullanet::aig::{Aig, Lit};
use nullanet::artifact::{CompiledLayer, CompiledModel, LayerStats};
use nullanet::coordinator::engine;
use nullanet::model::{Arch, Tensor};
use nullanet::netlist::LogicTape;
use nullanet::synth;
use nullanet::util::{SplitMix64, W256, W512};

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("nullanet_artifact_{tag}"));
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn random_tape(rng: &mut SplitMix64, n_pis: usize, n_ands: usize, n_outs: usize) -> LogicTape {
    let mut g = Aig::new(n_pis);
    let mut lits: Vec<Lit> = (0..n_pis).map(|i| g.pi(i)).collect();
    for _ in 0..n_ands {
        let a = lits[rng.range(0, lits.len())];
        let b = lits[rng.range(0, lits.len())];
        let a = if rng.bool(0.5) { a.not() } else { a };
        let b = if rng.bool(0.5) { b.not() } else { b };
        lits.push(g.and(a, b));
    }
    for _ in 0..n_outs {
        let o = lits[rng.range(0, lits.len())];
        g.add_output(if rng.bool(0.5) { o.not() } else { o });
    }
    LogicTape::from_aig(&g)
}

fn model_with(
    tapes: Vec<LogicTape>,
    params: BTreeMap<String, Tensor>,
    arch: Arch,
) -> CompiledModel {
    CompiledModel {
        name: "test".into(),
        arch,
        accuracy_test: f64::NAN,
        layers: tapes
            .into_iter()
            .enumerate()
            .map(|(i, tape)| CompiledLayer {
                name: format!("layer{}", i + 2),
                tape,
                stats: LayerStats { n_distinct: 1 + i, ..Default::default() },
            })
            .collect(),
        params,
        provenance: None,
    }
}

/// Parameters for the 2-2-2-2 test MLP (first layer thresholds the two
/// inputs at 0.5, last layer is identity) — mirrors the engine unit
/// tests' tiny net.
fn tiny_params() -> BTreeMap<String, Tensor> {
    let t = |shape: Vec<usize>, f32s: Vec<f32>| Tensor { shape, f32s };
    let mut m = BTreeMap::new();
    m.insert("w1".to_string(), t(vec![2, 2], vec![1.0, 0.0, 0.0, 1.0]));
    m.insert("scale1".to_string(), t(vec![2], vec![1.0, 1.0]));
    m.insert("bias1".to_string(), t(vec![2], vec![-0.5, -0.5]));
    m.insert("w3".to_string(), t(vec![2, 2], vec![1.0, 0.0, 0.0, 1.0]));
    m.insert("scale3".to_string(), t(vec![2], vec![1.0, 1.0]));
    m.insert("bias3".to_string(), t(vec![2], vec![0.0, 0.0]));
    m
}

/// Tape for the 2-bit swap layer: out0 = in1, out1 = in0.
fn swap_tape() -> LogicTape {
    let mut g = Aig::new(2);
    let (a, b) = (g.pi(0), g.pi(1));
    g.add_output(b);
    g.add_output(a);
    LogicTape::from_aig(&g)
}

#[test]
fn save_load_eval_bit_identical_at_every_width() {
    let dir = tmpdir("widths");
    let mut rng = SplitMix64::new(7);
    for case in 0..6 {
        let n = rng.range(2, 12);
        let (na, no) = (rng.range(1, 120), rng.range(1, 6));
        let tape = random_tape(&mut rng, n, na, no);
        let cm = model_with(
            vec![tape.clone()],
            BTreeMap::new(),
            Arch::Mlp { sizes: vec![n, n, n, n] },
        );
        let path = dir.join(format!("m{case}.nnc"));
        cm.save(&path).unwrap();
        let loaded = CompiledModel::load(&path).unwrap();
        let lt = &loaded.layers[0].tape;
        assert_eq!(*lt, tape, "loaded tape not structurally identical");
        let rows: Vec<Vec<bool>> = (0..512)
            .map(|_| (0..n).map(|_| rng.bool(0.5)).collect())
            .collect();
        for chunk in rows.chunks(64) {
            assert_eq!(lt.eval_batch_wide::<u64>(chunk), tape.eval_batch_wide::<u64>(chunk));
        }
        for chunk in rows.chunks(256) {
            assert_eq!(lt.eval_batch_wide::<W256>(chunk), tape.eval_batch_wide::<W256>(chunk));
        }
        assert_eq!(lt.eval_batch_wide::<W512>(&rows), tape.eval_batch_wide::<W512>(&rows));
    }
}

#[test]
fn params_and_stats_roundtrip_bitwise() {
    let dir = tmpdir("params");
    let mut rng = SplitMix64::new(3);
    let tape = random_tape(&mut rng, 4, 10, 2);
    let mut params = BTreeMap::new();
    params.insert(
        "w1".to_string(),
        Tensor { shape: vec![2, 3], f32s: vec![0.5, -1.25, 3.0e-7, -0.0, 1.5e8, 0.1] },
    );
    params.insert(
        "bias1".to_string(),
        Tensor { shape: vec![4], f32s: (0..4).map(|_| rng.normal() as f32).collect() },
    );
    let mut cm = model_with(vec![tape], params, Arch::Mlp { sizes: vec![3, 4, 4, 2] });
    cm.layers[0].stats = LayerStats {
        n_distinct: 123,
        n_conflicts: 4,
        total_cubes: 56,
        total_literals: 789,
        ands_initial: 90,
        ands_final: 77,
        n_luts: 12,
        alms: 7,
        lut_depth: 3,
        isf_digest: 0xdead_beef_1234_5678,
        hw_registers: 44,
        hw_fmax_mhz: 512.25,
        hw_latency_ns: 3.75,
        hw_power_mw: 0.875,
    };
    let path = dir.join("m.nnc");
    cm.save(&path).unwrap();
    let loaded = CompiledModel::load(&path).unwrap();
    assert_eq!(loaded.name, cm.name);
    assert_eq!(loaded.arch, cm.arch);
    assert!(loaded.accuracy_test.is_nan());
    assert_eq!(loaded.layers[0].stats, cm.layers[0].stats);
    assert_eq!(loaded.params.len(), cm.params.len());
    for (k, t) in &cm.params {
        let lt = &loaded.params[k];
        assert_eq!(lt.shape, t.shape);
        let want: Vec<u32> = t.f32s.iter().map(|x| x.to_bits()).collect();
        let got: Vec<u32> = lt.f32s.iter().map(|x| x.to_bits()).collect();
        assert_eq!(got, want, "tensor {k} not bit-identical");
    }
}

#[test]
fn truncated_artifact_rejected() {
    let dir = tmpdir("trunc");
    let mut rng = SplitMix64::new(5);
    let tape = random_tape(&mut rng, 8, 200, 4);
    let cm = model_with(vec![tape], tiny_params(), Arch::Mlp { sizes: vec![8, 8, 8, 8] });
    let path = dir.join("full.nnc");
    cm.save(&path).unwrap();
    let bytes = std::fs::read(&path).unwrap();
    let cut_path = dir.join("cut.nnc");
    for frac in [1usize, 30, 60, 95] {
        let cut = bytes.len() * frac / 100;
        std::fs::write(&cut_path, &bytes[..cut]).unwrap();
        assert!(CompiledModel::load(&cut_path).is_err(), "cut at {frac}% must fail");
    }
    // Dropping just the footer line must also fail.
    let text = String::from_utf8(bytes).unwrap();
    let no_footer: String = text
        .lines()
        .filter(|l| !l.contains("\"end\":true"))
        .map(|l| format!("{l}\n"))
        .collect();
    std::fs::write(&cut_path, no_footer).unwrap();
    let err = CompiledModel::load(&cut_path).unwrap_err();
    assert!(format!("{err:#}").contains("truncated"), "{err:#}");
}

#[test]
fn corrupted_section_rejected() {
    let dir = tmpdir("corrupt");
    let mut rng = SplitMix64::new(6);
    let tape = random_tape(&mut rng, 6, 80, 3);
    let cm = model_with(vec![tape], BTreeMap::new(), Arch::Mlp { sizes: vec![6, 6, 6, 6] });
    let path = dir.join("ok.nnc");
    cm.save(&path).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    // Flip one digit inside the layer section's ops array: whatever it
    // decodes to afterwards, the digest (or the tape validator) must
    // catch it.
    let pos = text.find("\"ops\":[[").expect("ops present") + "\"ops\":[[".len();
    let mut bytes = text.into_bytes();
    let digit = pos + bytes[pos..].iter().position(|b| b.is_ascii_digit()).unwrap();
    bytes[digit] = if bytes[digit] == b'9' { b'0' } else { bytes[digit] + 1 };
    let bad = dir.join("bad.nnc");
    std::fs::write(&bad, &bytes).unwrap();
    assert!(CompiledModel::load(&bad).is_err(), "corrupted op value must be rejected");

    // Header tampering (model name) is caught by the footer chain
    // digest, which is seeded with the decoded header fields.
    let text = std::fs::read_to_string(&path).unwrap();
    assert!(text.contains("\"name\":\"test\""), "{text}");
    let renamed = text.replacen("\"name\":\"test\"", "\"name\":\"evil\"", 1);
    std::fs::write(&bad, renamed).unwrap();
    let err = CompiledModel::load(&bad).unwrap_err();
    assert!(format!("{err:#}").contains("digest"), "{err:#}");
}

#[test]
fn version_mismatch_rejected() {
    let dir = tmpdir("version");
    let cm = model_with(vec![swap_tape()], BTreeMap::new(), Arch::Mlp { sizes: vec![2, 2, 2, 2] });
    let path = dir.join("v1.nnc");
    cm.save(&path).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    assert!(text.contains("\"version\":1"), "{text}");
    let bumped = text.replacen("\"version\":1", "\"version\":99", 1);
    let path2 = dir.join("v99.nnc");
    std::fs::write(&path2, bumped).unwrap();
    let err = CompiledModel::load(&path2).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("version"), "{msg}");
}

#[test]
fn non_artifact_file_rejected() {
    let dir = tmpdir("magic");
    let p = dir.join("junk.nnc");
    std::fs::write(&p, "hello world\n").unwrap();
    assert!(CompiledModel::load(&p).is_err());
    std::fs::write(&p, "{\"magic\":\"something-else\",\"version\":1}\n").unwrap();
    let err = CompiledModel::load(&p).unwrap_err();
    assert!(format!("{err:#}").contains("magic"), "{err:#}");
}

#[test]
fn engine_from_loaded_artifact_serves_identical_predictions() {
    use nullanet::coordinator::engine::InferenceEngine;

    let dir = tmpdir("engine");
    let cm = model_with(vec![swap_tape()], tiny_params(), Arch::Mlp { sizes: vec![2, 2, 2, 2] });
    let path = dir.join("tiny.nnc");
    cm.save(&path).unwrap();
    let loaded = CompiledModel::load(&path).unwrap();

    let direct = engine::LogicEngine::<u64>::new(cm.to_net_artifacts(), cm.tapes()).unwrap();
    let images: Vec<Vec<f32>> = (0..200)
        .map(|i| vec![((i % 3) as f32) * 0.45, ((i % 7) as f32) * 0.15])
        .collect();
    let refs: Vec<&[f32]> = images.iter().map(|v| v.as_slice()).collect();
    let want = direct.infer_batch(&refs);
    // engine_from_artifact consumes the model (move semantics), so each
    // width gets its own clone of the loaded artifact.
    for width in [64usize, 256, 512] {
        let eng = engine::engine_from_artifact(loaded.clone(), width).unwrap();
        assert_eq!(eng.preferred_block(), width);
        let got = eng.infer_batch(&refs);
        assert_eq!(got, want, "width {width} logits differ from the synthesizing path");
    }
    // Swap semantics survive the round trip: (0.9, 0.1) -> class 1.
    let probe: Vec<&[f32]> = vec![&[0.9, 0.1]];
    let eng = engine::engine_from_artifact(loaded.clone(), 64).unwrap();
    let out = eng.infer_batch(&probe);
    assert_eq!(nullanet::model::argmax(&out[0]), 1);
    // One helper, one error message for unsupported widths.
    let err = engine::engine_from_artifact(loaded, 128).unwrap_err();
    assert!(format!("{err:#}").contains("unsupported plane width"), "{err:#}");
}

#[test]
fn compile_net_to_artifact_end_to_end() {
    use nullanet::coordinator::engine::InferenceEngine;

    let dir = tmpdir("compile");
    // Synthetic trained net: the hidden layer is a 2-bit swap observed
    // over all 4 input patterns (so synthesis has the full truth table).
    let mut buf: Vec<u8> = b"NACT".to_vec();
    buf.extend(1u32.to_le_bytes());
    buf.extend(6u32.to_le_bytes());
    buf.extend(b"layer2");
    buf.extend(2u32.to_le_bytes()); // n_in
    buf.extend(2u32.to_le_bytes()); // n_out
    buf.extend(4u32.to_le_bytes()); // n_samples
    buf.extend([0b00, 0b01, 0b10, 0b11]); // inputs
    buf.extend([0b00, 0b10, 0b01, 0b11]); // outputs (bits swapped)
    std::fs::write(dir.join("activations.bin"), &buf).unwrap();

    let net = nullanet::model::NetArtifacts {
        name: "tiny".into(),
        arch: Arch::Mlp { sizes: vec![2, 2, 2, 2] },
        tensors: tiny_params(),
        accuracy_test: f64::NAN,
        dir: dir.clone(),
        hlo: BTreeMap::new(),
        hlo_params: BTreeMap::new(),
        isf_layers: vec![],
    };
    let cfg = synth::SynthConfig { threads: 2, ..Default::default() };
    let (compiled, timings) = synth::compile_net(&net, 0, &cfg).unwrap();
    assert_eq!(compiled.layers.len(), 1);
    assert_eq!(timings.len(), 1);
    assert_eq!(compiled.layers[0].stats.n_distinct, 4);
    assert_ne!(compiled.layers[0].stats.isf_digest, 0);
    assert!(compiled.params.contains_key("w1") && compiled.params.contains_key("w3"));

    let path = dir.join("tiny.nnc");
    compiled.save(&path).unwrap();
    let loaded = CompiledModel::load(&path).unwrap();
    // Serve the loaded artifact: it behaves exactly like the 2-bit swap.
    let eng = engine::engine_from_artifact(loaded, 256).unwrap();
    let images: Vec<Vec<f32>> = vec![vec![0.9, 0.1], vec![0.1, 0.9]];
    let refs: Vec<&[f32]> = images.iter().map(|v| v.as_slice()).collect();
    let out = eng.infer_batch(&refs);
    assert_eq!(nullanet::model::argmax(&out[0]), 1); // swapped
    assert_eq!(nullanet::model::argmax(&out[1]), 0);
}
